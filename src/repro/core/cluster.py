"""Elastic shard cluster: a coordinator process in front of N per-shard
server processes, behind one versioned ``ShardMap``.

Topology. The global key space is ``n_slots`` fixed-forever *slots*
(``slot = fid % n_slots``; directory entries hash by path or — with the
``name_by_parent`` map flag — by parent directory, colocating one dir's
entries). Each slot is owned by exactly one **shard server** process
(``repro.core.server`` running a slot-subset ``ShardedBackend`` with its
own event loop, segmented WAL and checkpointing). The **coordinator**
(this module) owns the authoritative ``ShardMap``::

    {"v": version, "n_slots": S, "slots": [addr_idx per slot],
     "addrs": [[host, port], ...], "flags": {"name_by_parent": bool}}

The map rides in the coordinator's hello and its version is advertised
on EVERY reply frame (``FLAG_MAPV`` envelope) — epoch-style, so clients
learn about rebalances passively. A shard server answering an op for a
slot it does not (or no longer) serve raises ``StaleShardMap``; the
client refetches the map and retries, exactly mirroring ``StaleEpoch``
for id leases.

Transactions. ``begin`` and ``commit`` route through the coordinator:

  * **begin** snapshots the *effective vector* — per-slot max applied
    timestamps as reported by acked commits, capped below any prepared-
    but-undecided 2PC timestamp (``_floors``) so no snapshot can claim
    coverage of a commit that is not yet applied everywhere — then fans
    the cache-sync scans out to the shard servers.
  * **single-server commits** (all touched slots on one server) forward
    as one plain ``T_COMMIT``: the server's local ShardedBackend runs
    its fast path or in-process 2PC and logs ONE atomic WAL record; the
    reply's ``slot_ts`` advances the coordinator's reported vector.
  * **cross-server commits** run real presumed-abort 2PC with durable
    markers. Prepares go out sequentially in server order (deadlock
    avoidance); each participant validates under its slot locks, logs a
    ``prep`` marker + fsync, and KEEPS the locks. Any no-vote or error
    aborts the yes-voters (nothing logged: presumed abort). On unanimous
    yes the coordinator installs the floor, durably logs ``("xdec",
    txid, participants)``, then pushes ``T_DECIDE``; participants log a
    ``dec`` marker + fsync before applying at the prepared timestamps.
    In-doubt participants (prep without dec after a crash) re-pin their
    slot locks at recovery and ask ``T_RESOLVE``: "c" if the decision is
    logged, "pending" while the txn is still in flight, else "a". The
    coordinator also pushes unacked decisions itself (startup + a
    background retry), so either side recovering first converges — no
    acked commit is lost, nothing applies twice.

Rebalancing. ``T_REBALANCE`` (admin-gated) moves slots live: log
``mig-start`` → source freezes the slots under their commit locks and
exports (``mig-exported``) → target durably logs ``mig-in`` BEFORE
installing (``mig-imported``) → coordinator logs the bumped ``cmap``
(``mig-mapped``) and flips the map → source durably drops
(``mig-out``). Recovery rolls forward iff the target imported (its WAL
proves it), else rolls back by unfreezing the source; a startup sweep
re-sends drops for slots the map no longer assigns. While frozen, every
op on the slot answers ``StaleShardMap`` — clients stall into a
refetch+retry instead of reading torn state.

Run standalone::

    python -m repro.core.cluster --wal /tmp/coord \\
        --shard 127.0.0.1:7001 --shard 127.0.0.1:7002
"""
from __future__ import annotations

import argparse
import os
import signal
import socket
import subprocess
import sys
import threading
import time
import zlib
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core import obs, wire
from repro.core.api import BackendAPI, CommitReply
from repro.core.backend import BackendStats, BeginReply, TxnPayload
from repro.core.remote import RemoteBackend
from repro.core.server import BackendServer
from repro.core.types import BlockKey, CachePolicy, Conflict, FileId, Timestamp
from repro.core.wire import StaleShardMap

SyncVector = Tuple[Timestamp, ...]

_XDECS = obs.REGISTRY.counter(
    "faasfs_coord_decisions_total", help="durably logged 2PC decisions",
).labels()
_MIGRATIONS = obs.REGISTRY.counter(
    "faasfs_coord_migrations_total", help="completed slot migrations",
).labels()


# --------------------------------------------------------------------------- #
# ShardMap helpers
# --------------------------------------------------------------------------- #
def make_map(addrs: List[Tuple[str, int]], n_slots: int,
             name_by_parent: bool = False) -> Dict[str, Any]:
    return {
        "v": 1,
        "n_slots": n_slots,
        "slots": [i % len(addrs) for i in range(n_slots)],
        "addrs": [[h, p] for h, p in addrs],
        "flags": {"name_by_parent": bool(name_by_parent)},
    }


def slot_of_name(path: str, n_slots: int, by_parent: bool) -> int:
    key = path
    if by_parent:
        cut = path.rfind("/")
        key = path[:cut] if cut > 0 else "/"
    return zlib.crc32(key.encode()) % n_slots


def split_payload(payload: TxnPayload, n_slots: int,
                  by_parent: bool) -> Dict[int, TxnPayload]:
    """Partition one client payload into per-slot payloads (mirrors
    ``ShardedBackend._split`` — the partition function is wire contract)."""
    parts: Dict[int, TxnPayload] = {}

    def part(s: int) -> TxnPayload:
        p = parts.get(s)
        if p is None:
            local_read = (
                payload.read_ts[s]
                if isinstance(payload.read_ts, tuple)
                else payload.read_ts
            )
            p = TxnPayload(read_ts=local_read, read_only=payload.read_only)
            parts[s] = p
        return p

    def slot_fid(fid: int) -> int:
        return fid % n_slots

    for r in payload.reads:
        part(slot_fid(r.key[0])).reads.append(r)
    for w in payload.writes:
        part(slot_fid(w.key[0])).writes.append(w)
    for pred in payload.predicates:
        part(slot_fid(pred.file_id)).predicates.append(pred)
    for fid, new_len in payload.meta_updates.items():
        part(slot_fid(fid)).meta_updates[fid] = new_len
    for fid, ver in payload.meta_reads.items():
        part(slot_fid(fid)).meta_reads[fid] = ver
    for path, fid in payload.name_updates.items():
        part(slot_of_name(path, n_slots, by_parent)).name_updates[path] = fid
    for path, ver in payload.name_reads.items():
        part(slot_of_name(path, n_slots, by_parent)).name_reads[path] = ver
    if not parts:  # effect-free non-read-only txn: pure validation
        parts[0] = TxnPayload(
            read_ts=payload.read_ts[0]
            if isinstance(payload.read_ts, tuple)
            else payload.read_ts,
            read_only=payload.read_only,
        )
    return parts


# --------------------------------------------------------------------------- #
# coordinator backend (hosted by CoordinatorServer)
# --------------------------------------------------------------------------- #
class CoordinatorBackend(BackendAPI):
    """The cluster's transaction coordinator and map authority, shaped
    as a ``BackendAPI`` so ``BackendServer`` machinery (event loop,
    worker pools, WAL, checkpointing, id leases) hosts it unchanged.
    Its own durable state is tiny: the map, unacked 2PC decisions, and
    any migration in flight."""

    #: how long a read-your-writes visibility wait may block (a crashed
    #: participant holds its floor until it recovers; commits already
    #: durably decided must not wedge the acking worker forever)
    VISIBILITY_WAIT_S = 5.0

    def __init__(
        self,
        shard_addrs: List[Tuple[str, int]],
        n_slots: Optional[int] = None,
        block_size: int = 4096,
        policy: CachePolicy = CachePolicy.INVALIDATE,
        name_by_parent: bool = False,
        admin_token: Optional[str] = None,
        connect_timeout_s: float = 30.0,
    ):
        if not shard_addrs:
            raise ValueError("a cluster needs at least one shard server")
        n = n_slots if n_slots is not None else len(shard_addrs)
        self._block_size = block_size
        self.policy = policy
        self.admin_token = admin_token
        self.connect_timeout_s = connect_timeout_s
        self.map = make_map(list(shard_addrs), n, name_by_parent)
        self._map_logged = False  # replay of a cmap record sets this
        self.wal = None
        self.txid_epoch = 0       # CoordinatorServer stamps its epoch
        # RLock'd condition: export_snapshot runs inside freeze(), which
        # already holds the lock
        self._mu = threading.Condition(threading.RLock())
        self._reported: List[Timestamp] = [0] * n
        self._floors: Dict[Tuple, Dict[int, Timestamp]] = {}
        self._inflight: Set[Tuple] = set()       # prepared, pre-decision
        self._decisions: Dict[Tuple, Set[int]] = {}  # txid -> unacked idxs
        self._mig_pending: Optional[Tuple] = None    # (slots, src, dst)
        self._mig_block: Set[int] = set()
        self._seq = 0
        self._gts = 0
        self._next_fid = 1
        self._links: Dict[int, RemoteBackend] = {}
        self._stop = threading.Event()
        self._pusher: Optional[threading.Thread] = None
        self.stats_local = {"fast": 0, "cross": 0, "aborts": 0, "ro": 0}

    # -- map-derived partitioning -------------------------------------- #
    @property
    def n_slots(self) -> int:
        return self.map["n_slots"]

    @property
    def n_shards(self) -> int:
        """Sync-vector width for the hello (== n_slots, never the
        process count: rebalancing must not change the wire contract)."""
        return self.n_slots

    @property
    def block_size(self) -> int:
        return self._block_size

    def slot_of_fid(self, fid: FileId) -> int:
        return fid % self.n_slots

    def slot_of_block(self, key: BlockKey) -> int:
        return self.slot_of_fid(key[0])

    def slot_of_name(self, path: str) -> int:
        return slot_of_name(
            path, self.n_slots, self.map["flags"]["name_by_parent"]
        )

    def _owner(self, slot: int) -> int:
        return self.map["slots"][slot]

    def _link(self, idx: int) -> RemoteBackend:
        link = self._links.get(idx)
        if link is None:
            host, port = self.map["addrs"][idx]
            link = RemoteBackend(
                host, port, connect_timeout_s=self.connect_timeout_s,
                admin_token=self.admin_token,
            )
            self._links[idx] = link
        return link

    # -- timestamp algebra (vector over n_slots) ----------------------- #
    @property
    def zero_ts(self) -> SyncVector:
        return (0,) * self.n_slots

    def ts_geq(self, a, b) -> bool:
        return all(x >= y for x, y in zip(a, b))

    def snapshot_cache_ok(self, key, version, at_ts, last_sync_ts) -> bool:
        s = self.slot_of_block(key)
        return version <= at_ts[s] and last_sync_ts[s] >= at_ts[s]

    def _effective_locked(self) -> List[Timestamp]:
        """Reported vector capped below every outstanding prepare: a
        begin must never hand out a snapshot covering a timestamp whose
        commit is not yet applied on its shard."""
        eff = list(self._reported)
        for ts_map in self._floors.values():
            for s, ts in ts_map.items():
                if ts - 1 < eff[s]:
                    eff[s] = ts - 1
        return eff

    @property
    def latest_ts(self) -> SyncVector:
        with self._mu:
            return tuple(self._effective_locked())

    # ------------------------------------------------------------------ #
    # reads: proxied per the map (direct-reading clients bypass this)
    # ------------------------------------------------------------------ #
    def begin(self, last_sync_ts, cached_keys: Optional[Set[BlockKey]] = None,
              policy: Optional[CachePolicy] = None) -> BeginReply:
        # effective vector FIRST: each later per-server scan then covers
        # at least up to every component it claims
        with self._mu:
            read_vec = tuple(self._effective_locked())
            slot_map = list(self.map["slots"])
        last = self._as_vector(last_sync_ts)
        keys_by_srv: Dict[int, Set[BlockKey]] = {}
        if cached_keys is not None:
            for k in cached_keys:
                idx = slot_map[self.slot_of_block(k)]
                keys_by_srv.setdefault(idx, set()).add(k)
        updates: Dict[BlockKey, Tuple[Timestamp, bytes]] = {}
        invals: List[BlockKey] = []
        file_invals: List[FileId] = []
        for idx in sorted(set(slot_map)):
            keys = None if cached_keys is None else keys_by_srv.get(idx, set())
            try:
                r = self._link(idx).begin(tuple(last), keys, policy)
            except StaleShardMap:
                # mid-rebalance: the slots this server lost contribute
                # nothing; their cached keys must be dropped
                if keys:
                    invals.extend(keys)
                continue
            updates.update(r.updates)
            invals.extend(r.invalidations)
            file_invals.extend(r.file_invalidations)
        return BeginReply(read_vec, updates, invals, file_invals)

    def _as_vector(self, ts) -> SyncVector:
        if isinstance(ts, int):
            return (ts,) * self.n_slots
        return tuple(ts)

    def fetch_blocks(self, keys, at_ts=None):
        by_srv: Dict[int, List[int]] = {}
        slot_map = self.map["slots"]
        for i, key in enumerate(keys):
            by_srv.setdefault(slot_map[self.slot_of_block(key)], []).append(i)
        out: List[Optional[Tuple[Timestamp, bytes]]] = [None] * len(keys)
        for idx, idxs in by_srv.items():
            got = self._link(idx).fetch_blocks([keys[i] for i in idxs], at_ts)
            for i, entry in zip(idxs, got):
                out[i] = entry
        return out  # type: ignore[return-value]

    def fetch_metas(self, fids, at_ts=None):
        by_srv: Dict[int, List[int]] = {}
        slot_map = self.map["slots"]
        for i, fid in enumerate(fids):
            by_srv.setdefault(slot_map[self.slot_of_fid(fid)], []).append(i)
        out: List[Optional[Tuple[Timestamp, Any]]] = [None] * len(fids)
        for idx, idxs in by_srv.items():
            got = self._link(idx).fetch_metas([fids[i] for i in idxs], at_ts)
            for i, entry in zip(idxs, got):
                out[i] = entry
        return out

    def lookup_many(self, paths, at_ts=None):
        by_srv: Dict[int, List[int]] = {}
        slot_map = self.map["slots"]
        for i, path in enumerate(paths):
            by_srv.setdefault(slot_map[self.slot_of_name(path)], []).append(i)
        out: List[Optional[Tuple[Timestamp, Optional[FileId]]]] = (
            [None] * len(paths)
        )
        for idx, idxs in by_srv.items():
            got = self._link(idx).lookup_many([paths[i] for i in idxs], at_ts)
            for i, entry in zip(idxs, got):
                out[i] = entry
        return out  # type: ignore[return-value]

    def sync_files(self, reqs):
        out: Dict[FileId, Dict[BlockKey, Tuple[Timestamp, bytes]]] = {}
        by_srv: Dict[int, Dict[FileId, Dict[BlockKey, Timestamp]]] = {}
        slot_map = self.map["slots"]
        for fid, known in reqs.items():
            by_srv.setdefault(
                slot_map[self.slot_of_fid(fid)], {}
            )[fid] = known
        for idx, sub in by_srv.items():
            out.update(self._link(idx).sync_files(sub))
        return out

    def listdir(self, prefix, at_ts=None):
        out: List[Tuple[str, Timestamp, Optional[FileId]]] = []
        for idx in sorted(set(self.map["slots"])):
            out.extend(self._link(idx).listdir(prefix, at_ts))
        return sorted(out)

    def alloc_file_id(self) -> FileId:
        with self._mu:
            fid = self._next_fid
            self._next_fid += 1
            return fid

    def bump_fid_floor(self, floor: FileId) -> None:
        with self._mu:
            if floor > self._next_fid:
                self._next_fid = floor

    def set_wal(self, wal) -> None:
        self.wal = wal

    @property
    def stats(self) -> BackendStats:
        agg = BackendStats()
        for idx in sorted(set(self.map["slots"])):
            try:
                s = self._link(idx).stats
            except OSError:
                continue
            for f in (
                "commits", "aborts", "begins", "blocks_pushed",
                "blocks_invalidated", "block_fetches", "bytes_pushed",
                "validation_checks", "group_batches", "group_committed",
            ):
                setattr(agg, f, getattr(agg, f) + getattr(s, f))
        agg.commits += self.stats_local["cross"]
        agg.aborts += self.stats_local["aborts"]
        return agg

    # ------------------------------------------------------------------ #
    # commit: single-server forward or cross-server 2PC
    # ------------------------------------------------------------------ #
    def commit(self, payload: TxnPayload) -> CommitReply:
        if payload.read_only and not payload.has_effects():
            with self._mu:
                self.stats_local["ro"] += 1
                return CommitReply(self._gts)
        # a migration can flip ownership between routing and prepare; the
        # participant's StaleShardMap then means "re-route", not "fail"
        for _ in range(4):
            try:
                return self._commit_once(payload)
            except StaleShardMap:
                continue
        return self._commit_once(payload)

    def _commit_once(self, payload: TxnPayload) -> CommitReply:
        by_parent = self.map["flags"]["name_by_parent"]
        parts = split_payload(payload, self.n_slots, by_parent)
        with self._mu:
            deadline = time.monotonic() + self.VISIBILITY_WAIT_S
            while self._mig_block & set(parts):
                if not self._mu.wait(timeout=0.1) and \
                        time.monotonic() > deadline:
                    raise StaleShardMap("slots blocked for migration")
            slot_map = list(self.map["slots"])
        by_srv: Dict[int, Dict[int, TxnPayload]] = {}
        for s, p in parts.items():
            by_srv.setdefault(slot_map[s], {})[s] = p
        if len(by_srv) == 1:
            ((idx, _),) = by_srv.items()
            reply = self._link(idx).commit(payload)
            with self._mu:
                self.stats_local["fast"] += 1
                self._gts += 1
                gts = self._gts
                for s, ts in reply.slot_ts.items():
                    if ts > self._reported[s]:
                        self._reported[s] = ts
                self._mu.notify_all()
                self._wait_visible_locked(reply.slot_ts)
            return CommitReply(gts, reply.block_versions,
                               slot_ts=dict(reply.slot_ts))
        return self._commit_2pc(payload, by_srv)

    def _wait_visible_locked(self, slot_ts: Dict[int, Timestamp]) -> None:
        """Read-your-writes: don't ack until the effective vector covers
        this commit on every touched slot (a concurrent 2PC's floor may
        briefly cap a slot below a timestamp that is already applied)."""
        if not slot_ts:
            return
        deadline = time.monotonic() + self.VISIBILITY_WAIT_S
        while True:
            eff = self._effective_locked()
            if all(eff[s] >= ts for s, ts in slot_ts.items()):
                return
            if time.monotonic() > deadline:
                return  # crashed participant: visibility follows recovery
            self._mu.wait(timeout=0.05)

    def _commit_2pc(self, payload: TxnPayload,
                    by_srv: Dict[int, Dict[int, TxnPayload]]) -> CommitReply:
        with self._mu:
            self._seq += 1
            txid = (self.txid_epoch, self._seq)
            self._inflight.add(txid)
        order = sorted(by_srv)
        prepared: List[int] = []
        ts_map: Dict[int, Timestamp] = {}
        try:
            # phase 1: sequential prepares in server order (two
            # coordinato r workers can't deadlock two servers), slot
            # locks held at each yes-voter until the decision
            for idx in order:
                obj = {
                    "txid": list(txid),
                    "parts": {
                        s: wire.payload_to_obj(p)
                        for s, p in by_srv[idx].items()
                    },
                }
                r = self._link(idx)._call(wire.T_PREPARE, obj)
                prepared.append(idx)
                for s, ts in r["ts"].items():
                    ts_map[int(s)] = ts
        except BaseException as e:
            # presumed abort: nothing logged anywhere for an abort — a
            # participant finding no decision later resolves to "a"
            for idx in prepared:
                try:
                    self._link(idx)._call(
                        wire.T_DECIDE, {"txid": list(txid), "c": False}
                    )
                except Exception:
                    pass  # its recovery resolver will learn "a"
            with self._mu:
                self._inflight.discard(txid)
                if isinstance(e, Conflict):
                    self.stats_local["aborts"] += 1
            raise

        # unanimous yes: floor the snapshot vector BEFORE the decision
        # exists, so no begin can run ahead of an applying commit
        with self._mu:
            self._floors[txid] = dict(ts_map)
        obs.crash_point("pre-decide")
        if self.wal is not None:
            lsn = self.wal.append(("xdec", list(txid), order))
            self.wal.sync(lsn)
        _XDECS.inc()
        obs.crash_point("dec-logged")
        with self._mu:
            self._decisions[txid] = set(order)
            self._inflight.discard(txid)

        # phase 2: push the decision; a participant that died after
        # voting applies it at recovery instead (resolver / pusher) —
        # the commit is acked regardless, its outcome is already durable
        for idx in order:
            try:
                self._link(idx)._call(
                    wire.T_DECIDE, {"txid": list(txid), "c": True}
                )
            except Exception:
                continue  # decision stays unacked; the pusher retries
            self._ack_decision(txid, idx, by_srv[idx], ts_map)

        with self._mu:
            if txid not in self._decisions:
                # fully acked: _ack_decision removed the floor atomically
                # with the last ack; a partially-acked txn keeps its floor
                # (all slots) until the pusher lands the stragglers
                self._floors.pop(txid, None)
            self._gts += 1
            gts = self._gts
            self.stats_local["cross"] += 1
            self._mu.notify_all()
            self._wait_visible_locked(
                {s: ts_map[s] for idx in order for s in by_srv[idx]
                 if s in ts_map}
            )
        block_versions = {
            w.key: ts_map[self.slot_of_block(w.key)]
            for w in payload.writes
            if self.slot_of_block(w.key) in ts_map
        }
        return CommitReply(gts, block_versions, slot_ts=dict(ts_map))

    def _ack_decision(self, txid: Tuple, idx: int,
                      parts: Dict[int, TxnPayload],
                      ts_map: Dict[int, Timestamp]) -> None:
        with self._mu:
            for s in parts:
                ts = ts_map.get(s)
                if ts is not None:
                    if ts > self._reported[s]:
                        self._reported[s] = ts
            # the floor must keep capping EVERY slot of this txn until the
            # last participant acks: releasing slots one ack at a time
            # would let a begin observe the commit applied on one server
            # but not the other — a torn (non-serializable) read vector
            unacked = self._decisions.get(txid)
            if unacked is not None:
                unacked.discard(idx)
                if not unacked:
                    self._decisions.pop(txid, None)
                    self._floors.pop(txid, None)
            self._mu.notify_all()

    # ------------------------------------------------------------------ #
    # termination protocol + decision pushing
    # ------------------------------------------------------------------ #
    def resolve(self, txid: Tuple) -> Dict[str, str]:
        """Answer a recovered participant: committed / aborted / still
        deciding. Presumed abort: no logged decision and not in flight
        means no commit was ever decided."""
        txid = tuple(txid)
        with self._mu:
            if txid in self._decisions:
                return {"d": "c"}
            if txid in self._inflight:
                return {"d": "pending"}
        return {"d": "a"}

    def _push_decisions(self) -> None:
        with self._mu:
            work = [(t, sorted(idxs)) for t, idxs in self._decisions.items()]
        for txid, idxs in work:
            for idx in idxs:
                try:
                    r = self._link(idx)._call(
                        wire.T_DECIDE, {"txid": list(txid), "c": True}
                    )
                except Exception:
                    continue
                ts_map = {int(s): ts for s, ts in (r.get("ts") or {}).items()}
                with self._mu:
                    for s, ts in ts_map.items():
                        if ts > self._reported[s]:
                            self._reported[s] = ts
                    # as in _ack_decision: the floor releases all-or-
                    # nothing when the last participant acks
                    unacked = self._decisions.get(txid)
                    if unacked is not None:
                        unacked.discard(idx)
                        if not unacked:
                            self._decisions.pop(txid, None)
                            self._floors.pop(txid, None)
                    self._mu.notify_all()

    def _pusher_loop(self) -> None:
        while not self._stop.wait(0.25):
            try:
                with self._mu:
                    idle = not self._decisions
                if not idle:
                    self._push_decisions()
            except Exception:
                pass

    # ------------------------------------------------------------------ #
    # startup: connect, learn applied vectors, settle in-doubt txns,
    # finish (or roll back) an interrupted migration
    # ------------------------------------------------------------------ #
    def startup(self) -> None:
        deadline = time.monotonic() + self.connect_timeout_s
        statuses: Dict[int, Dict] = {}
        for idx in sorted(set(self.map["slots"])):
            while True:
                try:
                    statuses[idx] = self._link(idx)._call(
                        wire.T_SHARD_STATUS, {"digests": False}
                    )
                    break
                except (OSError, wire.WireError):
                    self._links.pop(idx, None)
                    if time.monotonic() > deadline:
                        raise
                    time.sleep(0.1)
        with self._mu:
            for st in statuses.values():
                for s, ts in st["applied"].items():
                    s = int(s)
                    if ts > self._reported[s]:
                        self._reported[s] = ts

        # settle reported in-doubt txns: logged decision -> commit will
        # be (re)pushed below; unknown -> presumed abort, push it now
        in_doubt: Set[Tuple] = set()
        for st in statuses.values():
            in_doubt.update(tuple(t) for t in st.get("in_doubt", ()))
        with self._mu:
            aborts = [t for t in in_doubt if t not in self._decisions]
        for txid in aborts:
            for idx in statuses:
                try:
                    self._link(idx)._call(
                        wire.T_DECIDE, {"txid": list(txid), "c": False}
                    )
                except Exception:
                    pass

        self._finish_migration(statuses)

        # drop sweep: a slot the map no longer assigns to a server must
        # not linger there (a crash between cmap and the drop ack); a
        # frozen slot the map STILL assigns there is an interrupted
        # rollback — unfreeze it
        for idx, st in statuses.items():
            held = {int(s) for s in st["slots"]}
            held.update(int(s) for s in st.get("frozen", ()))
            stray = sorted(
                s for s in held if self.map["slots"][s] != idx
            )
            thawable = sorted(
                int(s) for s in st.get("frozen", ())
                if self.map["slots"][int(s)] == idx
            )
            if stray:
                try:
                    self._link(idx)._call(
                        wire.T_MIG_DROP, {"slots": stray}
                    )
                except Exception:
                    pass
            if thawable:
                try:
                    self._link(idx)._call(
                        wire.T_MIG_ABORT, {"slots": thawable}
                    )
                except Exception:
                    pass

        self._push_decisions()
        if not self._map_logged and self.wal is not None:
            lsn = self.wal.append(("cmap", self.map))
            self.wal.sync(lsn)
            self._map_logged = True
        if self._pusher is None:
            t = threading.Thread(
                target=self._pusher_loop, name="faasfs-coord-push",
                daemon=True,
            )
            t.start()
            self._pusher = t

    def _finish_migration(self, statuses: Dict[int, Dict]) -> None:
        """Roll an interrupted rebalance forward iff the target durably
        imported the slots (its WAL has the ``mig-in``), else back."""
        pend = self._mig_pending
        if pend is None:
            return
        slots, src, dst = pend
        slots = [int(s) for s in slots]
        dst_st = statuses.get(dst)
        if dst_st is None:
            try:
                dst_st = self._link(dst)._call(
                    wire.T_SHARD_STATUS, {"digests": False}
                )
            except Exception:
                dst_st = {"slots": []}
        owned = {int(s) for s in dst_st["slots"]}
        if all(s in owned for s in slots):
            # roll forward: the import is durable — publish the map
            new_map = {
                **self.map,
                "v": self.map["v"] + 1,
                "slots": list(self.map["slots"]),
            }
            for s in slots:
                new_map["slots"][s] = dst
            if self.wal is not None:
                lsn = self.wal.append(("cmap", new_map))
                self.wal.sync(lsn)
            with self._mu:
                self.map = new_map
                self._map_logged = True
            try:
                self._link(src)._call(wire.T_MIG_DROP, {"slots": slots})
            except Exception:
                pass  # covered by the next startup's drop sweep
        else:
            # roll back: unfreeze the source, scrub any partial import
            try:
                self._link(src)._call(wire.T_MIG_ABORT, {"slots": slots})
            except Exception:
                pass
            try:
                self._link(dst)._call(wire.T_MIG_DROP, {"slots": slots})
            except Exception:
                pass
        self._mig_pending = None

    # ------------------------------------------------------------------ #
    # live rebalancing
    # ------------------------------------------------------------------ #
    def rebalance(self, slots: List[int], to_idx: int) -> Dict[str, Any]:
        slots = sorted(set(int(s) for s in slots))
        if not 0 <= to_idx < len(self.map["addrs"]):
            raise ValueError(f"no shard server #{to_idx}")
        if any(s < 0 or s >= self.n_slots for s in slots):
            raise ValueError(f"slots {slots} out of range")
        with self._mu:
            srcs: Dict[int, List[int]] = {}
            for s in slots:
                cur = self.map["slots"][s]
                if cur != to_idx:
                    srcs.setdefault(cur, []).append(s)
            if not srcs:
                return {"v": self.map["v"], "map": self.map}
            moving = [s for group in srcs.values() for s in group]
            self._mig_block.update(moving)
        try:
            for src, group in sorted(srcs.items()):
                if self.wal is not None:
                    lsn = self.wal.append(("mig-start", group, src, to_idx))
                    self.wal.sync(lsn)
                self._mig_pending = (group, src, to_idx)
                try:
                    states = self._link(src)._call(
                        wire.T_MIG_EXPORT, {"slots": group}
                    )["states"]
                    self._link(to_idx)._call(
                        wire.T_MIG_IMPORT, {"states": states}
                    )
                except BaseException:
                    # roll back. Order matters: durably CANCEL the
                    # mig-start marker (re-log the unchanged map) BEFORE
                    # unfreezing the source — the target may have durably
                    # imported before dying, and a coordinator restart
                    # must not roll forward onto a copy that went stale
                    # the moment the source resumed taking writes
                    if self.wal is not None:
                        lsn = self.wal.append(("cmap", self.map))
                        self.wal.sync(lsn)
                    self._mig_pending = None
                    try:
                        self._link(src)._call(
                            wire.T_MIG_ABORT, {"slots": group}
                        )
                    except Exception:
                        pass  # the startup sweep also unfreezes
                    try:  # scrub any partial import off the target
                        self._link(to_idx)._call(
                            wire.T_MIG_DROP, {"slots": group}
                        )
                    except Exception:
                        pass  # the startup sweep also drops strays
                    raise
                with self._mu:
                    new_map = {
                        **self.map,
                        "v": self.map["v"] + 1,
                        "slots": list(self.map["slots"]),
                    }
                    for s in group:
                        new_map["slots"][s] = to_idx
                if self.wal is not None:
                    lsn = self.wal.append(("cmap", new_map))
                    self.wal.sync(lsn)
                obs.crash_point("mig-mapped")
                with self._mu:
                    self.map = new_map
                    self._map_logged = True
                    self._mig_pending = None
                    self._mu.notify_all()
                _MIGRATIONS.inc()
                try:
                    self._link(src)._call(
                        wire.T_MIG_DROP, {"slots": group}
                    )
                except Exception:
                    pass  # idempotent; the startup sweep re-sends it
        finally:
            with self._mu:
                self._mig_block.difference_update(slots)
                self._mu.notify_all()
        return {"v": self.map["v"], "map": self.map}

    # ------------------------------------------------------------------ #
    # durability plumbing (WAL replay + checkpoint snapshot)
    # ------------------------------------------------------------------ #
    def replay_record(self, rec) -> None:
        kind = rec[0]
        if kind == "cmap":
            self.map = rec[1]
            self._map_logged = True
            n = self.map["n_slots"]
            if len(self._reported) != n:
                self._reported = [0] * n
            self._mig_pending = None
            return
        if kind == "xdec":
            txid = tuple(rec[1])
            self._decisions[txid] = set(rec[2])
            if txid[0] == self.txid_epoch and txid[1] > self._seq:
                self._seq = txid[1]
            return
        if kind == "mig-start":
            self._mig_pending = (list(rec[1]), rec[2], rec[3])
            return
        raise ValueError(f"unknown WAL record kind {kind!r}")

    @contextmanager
    def freeze(self):
        with self._mu:
            yield

    def export_snapshot(self) -> Dict:
        with self._mu:
            return {
                "kind": "coordinator",
                "n": self.n_slots,
                "map": self.map,
                "decisions": [
                    [list(t), sorted(idxs)]
                    for t, idxs in sorted(self._decisions.items())
                ],
                "seq": self._seq,
                "next_fid": self._next_fid,
            }

    def import_snapshot(self, snap: Dict) -> None:
        if snap.get("kind") != "coordinator":
            raise ValueError(f"snapshot kind={snap.get('kind')!r} is not "
                             "a coordinator checkpoint")
        with self._mu:
            self.map = snap["map"]
            self._map_logged = True
            n = self.map["n_slots"]
            if len(self._reported) != n:
                self._reported = [0] * n
            for t, idxs in snap["decisions"]:
                self._decisions[tuple(t)] = set(idxs)
            if snap["seq"] > self._seq:
                self._seq = snap["seq"]
            if snap["next_fid"] > self._next_fid:
                self._next_fid = snap["next_fid"]

    def close(self) -> None:
        self._stop.set()
        for link in self._links.values():
            try:
                link.close()
            except Exception:
                pass
        self._links.clear()


# --------------------------------------------------------------------------- #
# coordinator server process
# --------------------------------------------------------------------------- #
class CoordinatorServer(BackendServer):
    """``BackendServer`` hosting a ``CoordinatorBackend``: same event
    loop, worker pools, segmented WAL, checkpoint trigger and id leases —
    plus the map in the hello, the map version on every reply frame, and
    the cluster-control verbs."""

    def __init__(self, backend: CoordinatorBackend, **kw):
        kw.setdefault("admin_token", backend.admin_token)
        super().__init__(backend, **kw)
        backend.txid_epoch = self.epoch

    def start(self) -> "CoordinatorServer":
        # connect + settle BEFORE serving: a client must never observe a
        # coordinator whose in-doubt txns and map are still unsettled
        self.backend.startup()
        super().start()
        return self

    def shutdown(self, drain: bool = False,
                 drain_timeout_s: float = 10.0) -> None:
        super().shutdown(drain=drain, drain_timeout_s=drain_timeout_s)
        self.backend.close()

    def _hello(self) -> Dict[str, Any]:
        h = super()._hello()
        h["map"] = self.backend.map
        return h

    def reply_mapv(self) -> Optional[int]:
        return self.backend.map["v"]

    def _dispatch(self, msg_type: int, obj: Any) -> Any:
        be = self.backend
        if msg_type == wire.T_SHARDMAP:
            return {"map": be.map}
        if msg_type == wire.T_RESOLVE:
            return be.resolve(tuple(obj["txid"]))
        if msg_type == wire.T_REBALANCE:
            return be.rebalance(
                [int(s) for s in obj["slots"]], int(obj["to"])
            )
        return super()._dispatch(msg_type, obj)


# --------------------------------------------------------------------------- #
# cluster-aware client: coordinator for txns, direct shard links for reads
# --------------------------------------------------------------------------- #
class ClusterBackend(BackendAPI):
    """Client transport for a shard cluster. Transactions (begin /
    commit / leases) go through the coordinator; reads route DIRECTLY to
    the owning shard server per the cached ``ShardMap``. A read landing
    on a server that no longer owns the slot gets ``StaleShardMap``: the
    client refetches the map from the coordinator and retries — the
    rebalance is invisible to callers. The map version advertised on
    coordinator reply frames triggers the same refresh passively."""

    MAX_RETRIES = 6

    def __init__(self, host: str, port: int, lease_size: int = 64,
                 admin_token: Optional[str] = None,
                 connect_timeout_s: float = 10.0):
        self.coord = RemoteBackend(
            host, port, lease_size=lease_size,
            connect_timeout_s=connect_timeout_s,
            admin_token=admin_token,
        )
        self._admin_token = admin_token
        self._connect_timeout_s = connect_timeout_s
        self._mu = threading.Lock()
        self._links: Dict[Tuple[str, int], RemoteBackend] = {}
        m = (self.coord._hello or {}).get("map")
        if m is None:
            m = self.coord._call(wire.T_SHARDMAP, None)["map"]
        self._map: Dict[str, Any] = m
        self.map_refreshes = 0

    # -- map handling --------------------------------------------------- #
    @property
    def shard_map(self) -> Dict[str, Any]:
        return self._map

    def _refresh_map(self) -> None:
        self._map = self.coord._call(wire.T_SHARDMAP, None)["map"]
        self.map_refreshes += 1

    def _maybe_refresh(self) -> None:
        v = self.coord.mapv_seen()
        if v is not None and v > self._map["v"]:
            self._refresh_map()

    def _link_for_slot(self, slot: int) -> RemoteBackend:
        host, port = self._map["addrs"][self._map["slots"][slot]]
        return self._link((host, port))

    def _link(self, addr: Tuple[str, int]) -> RemoteBackend:
        with self._mu:
            link = self._links.get(addr)
            if link is None:
                link = RemoteBackend(
                    addr[0], addr[1],
                    connect_timeout_s=self._connect_timeout_s,
                    admin_token=self._admin_token,
                )
                self._links[addr] = link
            return link

    def _retry(self, fn):
        """Run ``fn`` (which routes via the current map), refreshing the
        map and retrying on ``StaleShardMap`` — and on a dead shard link
        (its slots may have moved, taking the address out of the map)."""
        self._maybe_refresh()
        last: Optional[BaseException] = None
        for attempt in range(self.MAX_RETRIES):
            try:
                return fn()
            except StaleShardMap as e:
                last = e
            except wire.ConnectionClosed as e:
                last = e
            time.sleep(0 if attempt == 0 else 0.05 * attempt)
            self._refresh_map()
        raise last  # type: ignore[misc]

    # -- partitioning (mirrors the map, including the name flag) -------- #
    def slot_of_fid(self, fid: FileId) -> int:
        return fid % self._map["n_slots"]

    def slot_of_block(self, key: BlockKey) -> int:
        return self.slot_of_fid(key[0])

    def slot_of_name(self, path: str) -> int:
        return slot_of_name(
            path, self._map["n_slots"],
            self._map["flags"]["name_by_parent"],
        )

    # -- handshake-derived + algebra (delegate to the coordinator) ------ #
    @property
    def block_size(self) -> int:
        return self.coord.block_size

    @property
    def policy(self) -> CachePolicy:
        return self.coord.policy

    @property
    def n_shards(self) -> int:
        return self.coord.n_shards

    @property
    def zero_ts(self):
        return self.coord.zero_ts

    def ts_geq(self, a, b) -> bool:
        return self.coord.ts_geq(a, b)

    def snapshot_cache_ok(self, key, version, at_ts, last_sync_ts) -> bool:
        return self.coord.snapshot_cache_ok(key, version, at_ts, last_sync_ts)

    # -- coordinator-routed ops ----------------------------------------- #
    def begin(self, last_sync_ts, cached_keys=None, policy=None):
        return self.coord.begin(last_sync_ts, cached_keys, policy)

    def commit(self, payload) -> CommitReply:
        return self.coord.commit(payload)

    def alloc_file_id(self) -> FileId:
        return self.coord.alloc_file_id()

    @property
    def stats(self):
        return self.coord.stats

    @property
    def latest_ts(self):
        return self.coord.latest_ts

    def ping(self) -> None:
        self.coord.ping()

    def checkpoint(self) -> Dict[str, int]:
        return self.coord.checkpoint()

    def rebalance(self, slots: List[int], to_idx: int) -> Dict[str, Any]:
        out = self.coord._call(
            wire.T_REBALANCE, {"slots": list(slots), "to": to_idx}
        )
        self._map = out["map"]
        return out

    # -- direct-to-shard reads ------------------------------------------ #
    def fetch_blocks(self, keys, at_ts=None):
        def run():
            by_link: Dict[RemoteBackend, List[int]] = {}
            for i, key in enumerate(keys):
                by_link.setdefault(
                    self._link_for_slot(self.slot_of_block(key)), []
                ).append(i)
            out = [None] * len(keys)
            for link, idxs in by_link.items():
                got = link.fetch_blocks([keys[i] for i in idxs], at_ts)
                for i, entry in zip(idxs, got):
                    out[i] = entry
            return out
        return self._retry(run)

    def fetch_metas(self, fids, at_ts=None):
        def run():
            by_link: Dict[RemoteBackend, List[int]] = {}
            for i, fid in enumerate(fids):
                by_link.setdefault(
                    self._link_for_slot(self.slot_of_fid(fid)), []
                ).append(i)
            out = [None] * len(fids)
            for link, idxs in by_link.items():
                got = link.fetch_metas([fids[i] for i in idxs], at_ts)
                for i, entry in zip(idxs, got):
                    out[i] = entry
            return out
        return self._retry(run)

    def lookup_many(self, paths, at_ts=None):
        def run():
            by_link: Dict[RemoteBackend, List[int]] = {}
            for i, path in enumerate(paths):
                by_link.setdefault(
                    self._link_for_slot(self.slot_of_name(path)), []
                ).append(i)
            out = [None] * len(paths)
            for link, idxs in by_link.items():
                got = link.lookup_many([paths[i] for i in idxs], at_ts)
                for i, entry in zip(idxs, got):
                    out[i] = entry
            return out
        return self._retry(run)

    def sync_files(self, reqs):
        def run():
            out: Dict[FileId, Dict] = {}
            by_link: Dict[RemoteBackend, Dict] = {}
            for fid, known in reqs.items():
                by_link.setdefault(
                    self._link_for_slot(self.slot_of_fid(fid)), {}
                )[fid] = known
            for link, sub in by_link.items():
                out.update(link.sync_files(sub))
            return out
        return self._retry(run)

    def listdir(self, prefix, at_ts=None):
        def run():
            out: List = []
            for addr_idx in sorted(set(self._map["slots"])):
                host, port = self._map["addrs"][addr_idx]
                out.extend(self._link((host, port)).listdir(prefix, at_ts))
            return sorted(out)
        return self._retry(run)

    def close(self) -> None:
        with self._mu:
            links, self._links = list(self._links.values()), {}
        for link in links:
            try:
                link.close()
            except Exception:
                pass
        self.coord.close()


# --------------------------------------------------------------------------- #
# subprocess harness (tests + benchmarks)
# --------------------------------------------------------------------------- #
class ClusterHarness:
    """Spawn a real cluster — N shard server processes + a coordinator
    process, each with its own WAL directory — and hand out cluster
    clients. Restart methods reuse each process's port so the ShardMap
    stays valid across crash/recovery tests."""

    def __init__(
        self,
        root: str,
        n_servers: int = 2,
        n_slots: Optional[int] = None,
        block_size: int = 4096,
        policy: str = "invalidate",
        admin_token: Optional[str] = "cluster-secret",
        name_by_parent: bool = False,
        commit_service_s: float = 0.0,
        checkpoint_records: Optional[int] = None,
        startup_timeout_s: float = 30.0,
    ):
        self.root = root
        self.n_servers = n_servers
        self.n_slots = n_slots if n_slots is not None else n_servers
        self.block_size = block_size
        self.policy = policy
        self.admin_token = admin_token
        self.name_by_parent = name_by_parent
        self.commit_service_s = commit_service_s
        self.checkpoint_records = checkpoint_records
        self.startup_timeout_s = startup_timeout_s
        self.shard_procs: List[Optional[subprocess.Popen]] = []
        self.shard_ports: List[int] = []
        self.coord_proc: Optional[subprocess.Popen] = None
        self.coord_port: int = 0
        self._env = dict(os.environ)
        src = os.path.join(
            os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.dirname(os.path.abspath(__file__))))), "src"
        )
        self._env["PYTHONPATH"] = src + os.pathsep + \
            self._env.get("PYTHONPATH", "")

    # -- process plumbing ----------------------------------------------- #
    def _launch(self, argv: List[str]) -> subprocess.Popen:
        return subprocess.Popen(
            [sys.executable, "-u", "-m"] + argv,
            stdout=subprocess.PIPE,
            stderr=subprocess.DEVNULL,
            env=self._env,
            text=True,
        )

    @staticmethod
    def _await_port(proc: subprocess.Popen) -> int:
        line = proc.stdout.readline()
        if not line.startswith("LISTENING"):
            proc.kill()
            raise RuntimeError(f"server failed to start: {line!r}")
        return int(line.split()[1])

    def _spawn(self, argv: List[str]) -> Tuple[subprocess.Popen, int]:
        proc = self._launch(argv)
        return proc, self._await_port(proc)

    def _slots_of(self, i: int) -> str:
        return ",".join(
            str(s) for s in range(self.n_slots) if s % self.n_servers == i
        )

    def _shard_argv(self, i: int, port: int,
                    crash_at: Optional[str] = None) -> List[str]:
        argv = [
            "repro.core.server",
            "--port", str(port),
            "--wal", os.path.join(self.root, f"shard-{i}"),
            "--slots", self._slots_of(i),
            "--n-slots", str(self.n_slots),
            "--block-size", str(self.block_size),
            "--policy", self.policy,
            "--log-level", "off",
        ]
        if self.admin_token:
            argv += ["--admin-token", self.admin_token]
        if self.name_by_parent:
            argv += ["--name-by-parent"]
        if self.commit_service_s:
            argv += ["--commit-service", str(self.commit_service_s)]
        if self.checkpoint_records is not None:
            argv += ["--checkpoint-records", str(self.checkpoint_records)]
        if self.coord_port:
            argv += ["--coordinator", f"127.0.0.1:{self.coord_port}"]
        if crash_at:
            argv += ["--crash-at", crash_at]
        return argv

    def _coord_argv(self, port: int,
                    crash_at: Optional[str] = None) -> List[str]:
        argv = [
            "repro.core.cluster",
            "--port", str(port),
            "--wal", os.path.join(self.root, "coord"),
            "--n-slots", str(self.n_slots),
            "--block-size", str(self.block_size),
            "--policy", self.policy,
            "--log-level", "off",
        ]
        for p in self.shard_ports:
            argv += ["--shard", f"127.0.0.1:{p}"]
        if self.admin_token:
            argv += ["--admin-token", self.admin_token]
        if self.name_by_parent:
            argv += ["--name-by-parent"]
        if crash_at:
            argv += ["--crash-at", crash_at]
        return argv

    # -- lifecycle ------------------------------------------------------- #
    def start(self) -> "ClusterHarness":
        # launch every shard process first, THEN collect their ports:
        # interpreter startup overlaps instead of serializing
        self.shard_procs = [
            self._launch(self._shard_argv(i, 0))
            for i in range(self.n_servers)
        ]
        self.shard_ports = [self._await_port(p) for p in self.shard_procs]
        self.coord_proc, self.coord_port = self._spawn(self._coord_argv(0))
        return self

    def client(self, admin: bool = True) -> ClusterBackend:
        return ClusterBackend(
            "127.0.0.1", self.coord_port,
            admin_token=self.admin_token if admin else None,
        )

    def kill_shard(self, i: int) -> None:
        proc = self.shard_procs[i]
        if proc is not None and proc.poll() is None:
            proc.kill()
        if proc is not None:
            proc.wait(timeout=10)
        self.shard_procs[i] = None

    def restart_shard(self, i: int,
                      crash_at: Optional[str] = None) -> None:
        self.kill_shard(i)
        proc, port = self._spawn(
            self._shard_argv(i, self.shard_ports[i], crash_at=crash_at)
        )
        self.shard_procs[i] = proc
        assert port == self.shard_ports[i]

    def wait_shard_dead(self, i: int, timeout_s: float = 15.0) -> None:
        proc = self.shard_procs[i]
        if proc is not None:
            proc.wait(timeout=timeout_s)

    def kill_coordinator(self) -> None:
        if self.coord_proc is not None and self.coord_proc.poll() is None:
            self.coord_proc.kill()
        if self.coord_proc is not None:
            self.coord_proc.wait(timeout=10)
        self.coord_proc = None

    def restart_coordinator(self, crash_at: Optional[str] = None) -> None:
        self.kill_coordinator()
        proc, port = self._spawn(
            self._coord_argv(self.coord_port, crash_at=crash_at)
        )
        self.coord_proc = proc
        assert port == self.coord_port

    def wait_coordinator_dead(self, timeout_s: float = 15.0) -> None:
        if self.coord_proc is not None:
            self.coord_proc.wait(timeout=timeout_s)

    def stop(self) -> None:
        procs = [p for p in self.shard_procs if p is not None]
        if self.coord_proc is not None:
            procs.append(self.coord_proc)
        for p in procs:
            if p.poll() is None:
                p.send_signal(signal.SIGTERM)
        deadline = time.monotonic() + 10
        for p in procs:
            try:
                p.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=5)
        self.shard_procs = []
        self.coord_proc = None


# --------------------------------------------------------------------------- #
# standalone entry point
# --------------------------------------------------------------------------- #
def main(argv=None) -> None:
    from repro.core import wal as walmod

    p = argparse.ArgumentParser(description="FaaSFS cluster coordinator")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0)
    p.add_argument("--wal", default=None,
                   help="coordinator durable log directory")
    p.add_argument("--sync-mode", default="fsync", choices=walmod.SYNC_MODES)
    p.add_argument("--shard", action="append", default=[],
                   metavar="HOST:PORT",
                   help="shard server address (repeat per server)")
    p.add_argument("--n-slots", type=int, default=None,
                   help="total slots (default: number of --shard servers)")
    p.add_argument("--block-size", type=int, default=4096)
    p.add_argument("--policy", default="invalidate")
    p.add_argument("--admin-token", default=None)
    p.add_argument("--name-by-parent", action="store_true")
    p.add_argument("--checkpoint-bytes", type=int, default=None)
    p.add_argument("--checkpoint-records", type=int, default=None)
    p.add_argument("--checkpoint-interval", type=float, default=0.25)
    p.add_argument("--max-inflight", type=int, default=64)
    p.add_argument("--log-level", default="info",
                   choices=("debug", "info", "warn", "error", "off"))
    p.add_argument("--crash-at", default=None)
    args = p.parse_args(argv)

    obs.LOG.set_level(args.log_level)
    if args.crash_at:
        obs.CRASH_POINTS.add(args.crash_at)
    addrs = []
    for spec in args.shard:
        host, _, port = spec.rpartition(":")
        addrs.append((host, int(port)))
    backend = CoordinatorBackend(
        addrs,
        n_slots=args.n_slots,
        block_size=args.block_size,
        policy=CachePolicy(args.policy),
        name_by_parent=args.name_by_parent,
        admin_token=args.admin_token,
    )
    server = CoordinatorServer(
        backend, host=args.host, port=args.port,
        wal_path=args.wal, sync_mode=args.sync_mode,
        max_inflight_per_conn=args.max_inflight,
        checkpoint_bytes=args.checkpoint_bytes,
        checkpoint_records=args.checkpoint_records,
        checkpoint_interval_s=args.checkpoint_interval,
    )

    def _graceful(signum, frame):  # noqa: ARG001 - signal handler shape
        server._stop.set()

    signal.signal(signal.SIGTERM, _graceful)
    signal.signal(signal.SIGINT, _graceful)

    server.start()
    recovered = (server.recovery or {}).get("commits", 0)
    print(f"LISTENING {server.port} epoch={server.epoch} "
          f"recovered={recovered} mapv={backend.map['v']}", flush=True)
    server._stop.wait()
    server.shutdown(drain=True)
    print("SHUTDOWN clean", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:])
