"""The "NFS-like" baseline the paper compares against (Figs 4-6).

Semantics modeled after close-to-open-consistency NFS with server-side
locking:

  * every metadata operation and lock acquisition is a *blocking* round
    trip to the server (simulated with a configurable latency),
  * writes go through to the server (write-through on close/fsync),
  * client caches are invalidated at **whole-file granularity** whenever the
    file changes — the exact behavior the paper blames for NFS's 10x TPC-C
    collapse from 1 -> 2 clients ("clients must invalidate an entire cached
    file whenever any part of it changes").

The benchmark harness runs identical workloads over this and over FaaSFS.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class _File:
    data: bytearray
    version: int = 0


class NFSServer:
    """A lock-based shared file server with per-file versioning."""

    def __init__(self, rpc_latency_s: float = 0.0):
        self.rpc_latency_s = rpc_latency_s
        self._files: Dict[str, _File] = {}
        self._locks: Dict[str, threading.Lock] = {}
        self._mu = threading.Lock()
        self.rpcs = 0

    def _rpc(self) -> None:
        with self._mu:
            self.rpcs += 1
        if self.rpc_latency_s:
            time.sleep(self.rpc_latency_s)

    def lock(self, path: str) -> None:
        self._rpc()
        with self._mu:
            lk = self._locks.setdefault(path, threading.Lock())
        lk.acquire()

    def unlock(self, path: str) -> None:
        self._rpc()
        self._locks[path].release()

    def getattr(self, path: str) -> Tuple[int, int]:
        self._rpc()
        with self._mu:
            f = self._files.get(path)
            if f is None:
                raise FileNotFoundError(path)
            return len(f.data), f.version

    def create(self, path: str) -> None:
        self._rpc()
        with self._mu:
            self._files.setdefault(path, _File(bytearray()))

    def read_all(self, path: str) -> Tuple[bytes, int]:
        self._rpc()
        with self._mu:
            f = self._files.get(path)
            if f is None:
                raise FileNotFoundError(path)
            return bytes(f.data), f.version

    def write(self, path: str, offset: int, data: bytes) -> int:
        self._rpc()
        with self._mu:
            f = self._files.setdefault(path, _File(bytearray()))
            if len(f.data) < offset + len(data):
                f.data.extend(b"\0" * (offset + len(data) - len(f.data)))
            f.data[offset : offset + len(data)] = data
            f.version += 1
            return f.version

    def exists(self, path: str) -> bool:
        self._rpc()
        with self._mu:
            return path in self._files


class NFSClient:
    """Whole-file caching client with close-to-open consistency."""

    def __init__(self, server: NFSServer):
        self.server = server
        self.cache: Dict[str, Tuple[bytes, int]] = {}
        self.hits = 0
        self.misses = 0

    def open(self, path: str, create: bool = False) -> str:
        if create and not self.server.exists(path):
            self.server.create(path)
        # close-to-open: revalidate on open — whole-file invalidation
        try:
            size, version = self.server.getattr(path)
        except FileNotFoundError:
            if not create:
                raise
            self.server.create(path)
            size, version = self.server.getattr(path)
        ent = self.cache.get(path)
        if ent is None or ent[1] != version:
            self.cache.pop(path, None)
        return path

    def read(self, path: str, offset: int, size: int) -> bytes:
        ent = self.cache.get(path)
        if ent is None:
            data, version = self.server.read_all(path)
            self.cache[path] = (data, version)
            self.misses += 1
        else:
            data = ent[0]
            self.hits += 1
        return data[offset : offset + size]

    def write(self, path: str, offset: int, data: bytes) -> None:
        # write-through; our own cache copy is patched, other clients
        # invalidate the whole file on next open
        version = self.server.write(path, offset, data)
        ent = self.cache.get(path)
        if ent is not None:
            buf = bytearray(ent[0])
            if len(buf) < offset + len(data):
                buf.extend(b"\0" * (offset + len(data) - len(buf)))
            buf[offset : offset + len(data)] = data
            self.cache[path] = (bytes(buf), version)

    def lock(self, path: str) -> None:
        self.server.lock(path)

    def unlock(self, path: str) -> None:
        self.server.unlock(path)
