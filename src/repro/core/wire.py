"""Wire protocol for the networked FaaSFS transport.

Two layers, both self-contained (no third-party dependency):

**Codec** — a msgpack-shaped binary encoding (`pack` / `unpack`) for the
value trees the RPCs exchange: None, bool, signed 64-bit ints, float64,
bytes, str, list, dict, and tuple. The format follows the real msgpack
tag layout (fixint / fixstr / fixarray / fixmap, bin8/16/32, str8/16/32,
array16/32, map16/32, int/uint families, ext) so the bytes are readable
by any msgpack decoder that understands ext type 1 = tuple. Tuples need
their own ext tag because the protocol round-trips dict keys like
``BlockKey = (file_id, block_index)`` — decoding arrays as lists would
make them unhashable.

**Frames** — every message on the socket is ``header || body`` (wire v2):

    header = MAGIC(1) | VERSION(1) | MSG_TYPE(1) | FLAGS(1)
           | REQUEST_ID(4, BE) | BODY_LEN(4, BE)

The FLAGS byte (the v2 pad byte, always 0 until now, so untraced
traffic is byte-identical) carries ``FLAG_TRACE``: when set, a 16-byte
trace envelope ``TRACE_ID(8, BE) | SPAN_ID(8, BE)`` sits between the
header and the body (``BODY_LEN`` still counts only the codec body).
That is how a client propagates its sampling decision and trace
context to the server — the flag IS the sampled bit — so server-side
spans (queue wait, worker exec, WAL fsync) land in the same Perfetto
timeline as the client RPC that caused them. See ``core/obs.py``.

A peer that sees a wrong magic or an unsupported version drops the
connection instead of guessing. The message-type byte selects the RPC
(requests) or the outcome (``T_OK`` / ``T_ERR`` responses); bodies are
codec-packed value trees. The request id (new in v2) correlates replies
with requests, so MANY requests can be in flight on one connection and
the server may answer them out of order as handlers finish — the client
multiplexes futures by id instead of holding a pool of one-at-a-time
connections (v1's model). Id 0 is reserved for unsolicited server
frames (the hello).

This module also pins down the *object conversions* between the typed
dataclasses (``TxnPayload`` / ``BeginReply`` / ``CommitReply`` /
``BackendStats``) and plain value trees, and the exception mapping that
lets ``Conflict`` (with its keys, including ``LengthPredicate``),
``NotFound``, ``SnapshotTooOld`` etc. propagate across the socket.
"""
from __future__ import annotations

import dataclasses
import struct
from dataclasses import asdict
from typing import Any, Dict, List, Optional, Tuple

from repro.core.types import (
    Conflict,
    Exists,
    LengthPredicate,
    NotFound,
    PredicateKind,
    ReadRecord,
    TxnStateError,
    WriteRecord,
)

# --------------------------------------------------------------------------- #
# protocol constants
# --------------------------------------------------------------------------- #
MAGIC = 0xF5
VERSION = 3  # v3: fetch_meta(s) replies carry (ver, length, exists, kind, mtime_ts)
_HEADER = struct.Struct(">BBBBII")
HEADER_LEN = _HEADER.size

#: header FLAGS bit: a 16-byte (trace_id, span_id) envelope follows the
#: header; the frame is part of a sampled trace
FLAG_TRACE = 0x01
_TRACE = struct.Struct(">QQ")
TRACE_LEN = _TRACE.size

#: header FLAGS bit: a 4-byte BE shard-map version envelope follows the
#: header (after the trace envelope when both are set). Cluster
#: coordinators stamp it on every reply, epoch-style, so a client
#: learns its routing map went stale without an extra round trip.
FLAG_MAPV = 0x02
_MAPV = struct.Struct(">I")
MAPV_LEN = _MAPV.size

# responses
T_HELLO = 0x01
T_OK = 0x02
T_ERR = 0x03
# requests (scalar, v1 heritage)
T_BEGIN = 0x10
T_SYNC_FILE = 0x11
T_FETCH_BLOCK = 0x12
T_FETCH_META = 0x13
T_LOOKUP = 0x14
T_LISTDIR = 0x15
T_COMMIT = 0x16
T_ALLOC_RANGE = 0x17
T_STATS = 0x18
T_LATEST_TS = 0x19
T_PING = 0x1A
# requests (batch, new in v2 — one frame, one reply, many items)
T_FETCH_BLOCKS = 0x20
T_FETCH_METAS = 0x21
T_LOOKUP_MANY = 0x22
T_SYNC_FILES = 0x23
# admin (v3): force a WAL checkpoint + compaction cycle; replies with the
# summary {seg, bytes, segments_removed}
T_CHECKPOINT = 0x24
# admin: dump the server's span ring buffer + slow-op log
# ({"spans": [...], "slow": [...]}); body {"clear": bool}
T_TRACE_DUMP = 0x25
# cluster (v3 shard scale-out) -------------------------------------------
# authenticate the connection for admin ops; body {"token": str}
T_AUTH = 0x26
# fetch the coordinator's current versioned ShardMap; body {}
T_SHARDMAP = 0x27
# 2PC participant ops (coordinator -> shard server)
T_PREPARE = 0x28
T_DECIDE = 0x29
# in-doubt resolution (shard server -> coordinator); body {"txid": [e, n]}
T_RESOLVE = 0x2A
# live rebalancing (coordinator -> shard server)
T_MIG_EXPORT = 0x2B
T_MIG_IMPORT = 0x2C
T_MIG_DROP = 0x2D
T_MIG_ABORT = 0x2E
# admin: trigger a slot migration on the coordinator
T_REBALANCE = 0x2F
# shard status probe: owned slots, applied ts, in-doubt txids, digests
T_SHARD_STATUS = 0x30
# lease tier (v3 cache coherence) ----------------------------------------
# acquire read leases; body {"f": [fid, ...], "m": "inv"|"push"} ->
# {"e": server_epoch, "ttl": ttl_s, "g": [fid, ...]} (granted subset)
T_LEASE = 0x31
# drop leases early; body {"f": [fid, ...]} -> {"r": n_released}
T_LEASE_RELEASE = 0x32
# server -> client push (request id 0): a commit touched leased files;
# body {"e": epoch, "f": [fid, ...], "n": [path, ...], "t": commit_ts,
# "us": server monotonic micros at send}
T_INVALIDATE = 0x33
# server -> client push (request id 0): T_INVALIDATE plus the committed
# block contents for the holder's leased files; body
# {"e": epoch, "f": [fid, ...], "n": [path, ...],
#  "b": {(fid, blk_idx): [ver, bytes]}, "t": commit_ts, "us": micros}
T_PUSH_VERSION = 0x34

#: human-readable op names for metrics/span labels (obs.py consumers
#: pre-bind label children from this table at import time)
MSG_NAMES = {
    T_HELLO: "hello", T_OK: "ok", T_ERR: "err",
    T_BEGIN: "begin", T_SYNC_FILE: "sync_file",
    T_FETCH_BLOCK: "fetch_block", T_FETCH_META: "fetch_meta",
    T_LOOKUP: "lookup", T_LISTDIR: "listdir", T_COMMIT: "commit",
    T_ALLOC_RANGE: "alloc_range", T_STATS: "stats",
    T_LATEST_TS: "latest_ts", T_PING: "ping",
    T_FETCH_BLOCKS: "fetch_blocks", T_FETCH_METAS: "fetch_metas",
    T_LOOKUP_MANY: "lookup_many", T_SYNC_FILES: "sync_files",
    T_CHECKPOINT: "checkpoint", T_TRACE_DUMP: "trace_dump",
    T_AUTH: "auth", T_SHARDMAP: "shardmap",
    T_PREPARE: "prepare", T_DECIDE: "decide", T_RESOLVE: "resolve",
    T_MIG_EXPORT: "mig_export", T_MIG_IMPORT: "mig_import",
    T_MIG_DROP: "mig_drop", T_MIG_ABORT: "mig_abort",
    T_REBALANCE: "rebalance", T_SHARD_STATUS: "shard_status",
    T_LEASE: "lease", T_LEASE_RELEASE: "lease_release",
    T_INVALIDATE: "invalidate", T_PUSH_VERSION: "push_version",
}

#: max body we will accept from a peer (a frame claiming more is corrupt)
MAX_BODY = 256 * 1024 * 1024

_EXT_TUPLE = 1

#: bytes payloads at least this large ride as their own scatter-gather
#: segment in a SendQueue instead of being copied into the frame buffer
SPILL_MIN = 2048


class WireError(Exception):
    """Malformed frame / codec bytes, or a protocol violation."""


class ConnectionClosed(WireError):
    """Peer closed the socket mid-conversation."""


class StaleEpoch(Exception):
    """A fenced request carried an epoch older than the server's current
    one (the server restarted since the client's lease was granted)."""


class StaleShardMap(Exception):
    """The request was routed with an out-of-date ShardMap: the target
    no longer owns the key range (slot migrated or frozen). The client
    must refetch the map from the coordinator and retry — the cluster
    analogue of ``StaleEpoch``."""


class PermissionDenied(Exception):
    """An admin-gated op (checkpoint, trace dump, rebalance, 2PC
    participant ops) was attempted on a connection that has not
    authenticated with the server's ``--admin-token``."""


class RemoteError(Exception):
    """Server-side exception of a type the client does not know."""


# --------------------------------------------------------------------------- #
# codec: pack
# --------------------------------------------------------------------------- #
def _pack_into(obj: Any, out: bytearray) -> None:
    if obj is None:
        out.append(0xC0)
    elif obj is True:
        out.append(0xC3)
    elif obj is False:
        out.append(0xC2)
    elif isinstance(obj, int):
        _pack_int(obj, out)
    elif isinstance(obj, float):
        out.append(0xCB)
        out += struct.pack(">d", obj)
    elif isinstance(obj, str):
        b = obj.encode("utf-8")
        n = len(b)
        if n <= 31:
            out.append(0xA0 | n)
        elif n <= 0xFF:
            out += bytes((0xD9, n))
        elif n <= 0xFFFF:
            out.append(0xDA)
            out += struct.pack(">H", n)
        else:
            out.append(0xDB)
            out += struct.pack(">I", n)
        out += b
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        b = bytes(obj)
        _pack_bin_header(len(b), out)
        out += b
    elif isinstance(obj, tuple):
        # ext type 1: payload is the packed element array
        inner = bytearray()
        _pack_array(obj, inner)
        n = len(inner)
        if n <= 0xFF:
            out += bytes((0xC7, n, _EXT_TUPLE))
        elif n <= 0xFFFF:
            out.append(0xC8)
            out += struct.pack(">H", n)
            out.append(_EXT_TUPLE)
        else:
            out.append(0xC9)
            out += struct.pack(">I", n)
            out.append(_EXT_TUPLE)
        out += inner
    elif isinstance(obj, list):
        _pack_array(obj, out)
    elif isinstance(obj, dict):
        _pack_map_header(len(obj), out)
        for k, v in obj.items():
            _pack_into(k, out)
            _pack_into(v, out)
    else:
        raise WireError(f"unpackable type {type(obj).__name__}")


def _pack_bin_header(n: int, out: bytearray) -> None:
    if n <= 0xFF:
        out += bytes((0xC4, n))
    elif n <= 0xFFFF:
        out.append(0xC5)
        out += struct.pack(">H", n)
    else:
        out.append(0xC6)
        out += struct.pack(">I", n)


def _pack_int(v: int, out: bytearray) -> None:
    if 0 <= v <= 0x7F:
        out.append(v)
    elif -32 <= v < 0:
        out.append(v & 0xFF)
    elif 0 < v:
        if v <= 0xFF:
            out += bytes((0xCC, v))
        elif v <= 0xFFFF:
            out.append(0xCD)
            out += struct.pack(">H", v)
        elif v <= 0xFFFFFFFF:
            out.append(0xCE)
            out += struct.pack(">I", v)
        elif v <= 0xFFFFFFFFFFFFFFFF:
            out.append(0xCF)
            out += struct.pack(">Q", v)
        else:
            raise WireError(f"int too large for wire: {v}")
    else:
        if v >= -0x80:
            out.append(0xD0)
            out += struct.pack(">b", v)
        elif v >= -0x8000:
            out.append(0xD1)
            out += struct.pack(">h", v)
        elif v >= -0x80000000:
            out.append(0xD2)
            out += struct.pack(">i", v)
        elif v >= -0x8000000000000000:
            out.append(0xD3)
            out += struct.pack(">q", v)
        else:
            raise WireError(f"int too small for wire: {v}")


def _pack_array_header(n: int, out: bytearray) -> None:
    if n <= 15:
        out.append(0x90 | n)
    elif n <= 0xFFFF:
        out.append(0xDC)
        out += struct.pack(">H", n)
    else:
        out.append(0xDD)
        out += struct.pack(">I", n)


def _pack_map_header(n: int, out: bytearray) -> None:
    if n <= 15:
        out.append(0x80 | n)
    elif n <= 0xFFFF:
        out.append(0xDE)
        out += struct.pack(">H", n)
    else:
        out.append(0xDF)
        out += struct.pack(">I", n)


def _pack_array(seq, out: bytearray) -> None:
    _pack_array_header(len(seq), out)
    for item in seq:
        _pack_into(item, out)


def pack(obj: Any) -> bytes:
    out = bytearray()
    _pack_into(obj, out)
    return bytes(out)


# --------------------------------------------------------------------------- #
# codec: unpack
# --------------------------------------------------------------------------- #
def _need(buf, off: int, n: int) -> None:
    if off + n > len(buf):
        raise WireError("truncated codec bytes")


def _unpack_from(buf, off: int, stats=None, sink=None) -> Tuple[Any, int]:
    _need(buf, off, 1)
    tag = buf[off]
    off += 1
    if tag <= 0x7F:                      # positive fixint
        return tag, off
    if tag >= 0xE0:                      # negative fixint
        return tag - 0x100, off
    if 0x80 <= tag <= 0x8F:              # fixmap
        return _unpack_map(buf, off, tag & 0x0F, stats, sink)
    if 0x90 <= tag <= 0x9F:              # fixarray
        return _unpack_list(buf, off, tag & 0x0F, stats, sink)
    if 0xA0 <= tag <= 0xBF:              # fixstr
        n = tag & 0x1F
        _need(buf, off, n)
        return bytes(buf[off : off + n]).decode("utf-8"), off + n
    if tag == 0xC0:
        return None, off
    if tag == 0xC2:
        return False, off
    if tag == 0xC3:
        return True, off
    if tag in (0xC4, 0xC5, 0xC6):        # bin
        n, off = _unpack_len(buf, off, tag - 0xC4)
        _need(buf, off, n)
        if sink is not None:
            dst = sink(n)
            if dst is not None:
                dst[:] = buf[off : off + n]
                if stats is not None and len(stats) > 1:
                    stats[1] += n
                return dst, off + n
        if stats is not None:
            stats[0] += n
        return bytes(buf[off : off + n]), off + n
    if tag in (0xC7, 0xC8, 0xC9):        # ext
        n, off = _unpack_len(buf, off, tag - 0xC7)
        _need(buf, off, 1)
        ext_type = buf[off]
        off += 1
        _need(buf, off, n)
        if ext_type != _EXT_TUPLE:
            raise WireError(f"unknown ext type {ext_type}")
        inner, ioff = _unpack_from(buf, off, stats, sink)
        if ioff != off + n or not isinstance(inner, list):
            raise WireError("malformed tuple ext payload")
        return tuple(inner), off + n
    if tag == 0xCB:
        _need(buf, off, 8)
        return struct.unpack_from(">d", buf, off)[0], off + 8
    if tag == 0xCC:
        _need(buf, off, 1)
        return buf[off], off + 1
    if tag == 0xCD:
        _need(buf, off, 2)
        return struct.unpack_from(">H", buf, off)[0], off + 2
    if tag == 0xCE:
        _need(buf, off, 4)
        return struct.unpack_from(">I", buf, off)[0], off + 4
    if tag == 0xCF:
        _need(buf, off, 8)
        return struct.unpack_from(">Q", buf, off)[0], off + 8
    if tag == 0xD0:
        _need(buf, off, 1)
        return struct.unpack_from(">b", buf, off)[0], off + 1
    if tag == 0xD1:
        _need(buf, off, 2)
        return struct.unpack_from(">h", buf, off)[0], off + 2
    if tag == 0xD2:
        _need(buf, off, 4)
        return struct.unpack_from(">i", buf, off)[0], off + 4
    if tag == 0xD3:
        _need(buf, off, 8)
        return struct.unpack_from(">q", buf, off)[0], off + 8
    if tag in (0xD9, 0xDA, 0xDB):        # str8/16/32
        n, off = _unpack_len(buf, off, tag - 0xD9)
        _need(buf, off, n)
        return bytes(buf[off : off + n]).decode("utf-8"), off + n
    if tag == 0xDC:
        _need(buf, off, 2)
        n = struct.unpack_from(">H", buf, off)[0]
        return _unpack_list(buf, off + 2, n, stats, sink)
    if tag == 0xDD:
        _need(buf, off, 4)
        n = struct.unpack_from(">I", buf, off)[0]
        return _unpack_list(buf, off + 4, n, stats, sink)
    if tag == 0xDE:
        _need(buf, off, 2)
        n = struct.unpack_from(">H", buf, off)[0]
        return _unpack_map(buf, off + 2, n, stats, sink)
    if tag == 0xDF:
        _need(buf, off, 4)
        n = struct.unpack_from(">I", buf, off)[0]
        return _unpack_map(buf, off + 4, n, stats, sink)
    raise WireError(f"unknown codec tag 0x{tag:02x}")


def _unpack_len(buf, off: int, width_idx: int) -> Tuple[int, int]:
    if width_idx == 0:
        _need(buf, off, 1)
        return buf[off], off + 1
    if width_idx == 1:
        _need(buf, off, 2)
        return struct.unpack_from(">H", buf, off)[0], off + 2
    _need(buf, off, 4)
    return struct.unpack_from(">I", buf, off)[0], off + 4


def _unpack_list(buf, off: int, n: int, stats=None, sink=None) -> Tuple[List[Any], int]:
    out = []
    for _ in range(n):
        v, off = _unpack_from(buf, off, stats, sink)
        out.append(v)
    return out, off


def _unpack_map(buf, off: int, n: int, stats=None, sink=None) -> Tuple[Dict[Any, Any], int]:
    out: Dict[Any, Any] = {}
    for _ in range(n):
        k, off = _unpack_from(buf, off, stats, sink)
        v, off = _unpack_from(buf, off, stats, sink)
        out[k] = v
    return out, off


def unpack(data: bytes) -> Any:
    obj, off = _unpack_from(memoryview(data), 0)
    if off != len(data):
        raise WireError(f"{len(data) - off} trailing byte(s) after value")
    return obj


# --------------------------------------------------------------------------- #
# frames
# --------------------------------------------------------------------------- #
_HDR_PAD = bytes(HEADER_LEN)


def encode_frame_into(out: bytearray, msg_type: int, obj: Any,
                      req_id: int = 0,
                      trace: Optional[Tuple[int, int]] = None) -> int:
    """Append one frame to ``out`` without intermediate allocations:
    reserve the header, pack the body in place, then patch the header
    with the measured body length. ``trace`` attaches a sampled
    ``(trace_id, span_id)`` envelope. Returns the frame length."""
    hdr_at = len(out)
    out += _HDR_PAD
    flags = 0
    if trace is not None:
        out += _TRACE.pack(trace[0], trace[1])
        flags = FLAG_TRACE
    body_at = len(out)
    _pack_into(obj, out)
    body_len = len(out) - body_at
    _HEADER.pack_into(out, hdr_at, MAGIC, VERSION, msg_type, flags,
                      req_id, body_len)
    return len(out) - hdr_at


def encode_frame(msg_type: int, obj: Any, req_id: int = 0,
                 trace: Optional[Tuple[int, int]] = None) -> bytes:
    out = bytearray()
    encode_frame_into(out, msg_type, obj, req_id, trace)
    return bytes(out)


def decode_header_ex(hdr, off: int = 0) -> Tuple[int, int, int, int]:
    """(msg_type, req_id, body_len, flags); raises WireError on bad
    magic/version. Accepts bytes or a memoryview, with an optional
    offset, so callers can decode in place without slicing a copy."""
    magic, version, msg_type, flags, req_id, body_len = \
        _HEADER.unpack_from(hdr, off)
    if magic != MAGIC:
        raise WireError(f"bad magic 0x{magic:02x}")
    if version != VERSION:
        raise WireError(f"unsupported wire version {version}")
    if body_len > MAX_BODY:
        raise WireError(f"frame body too large ({body_len} bytes)")
    return msg_type, req_id, body_len, flags


def decode_header(hdr, off: int = 0) -> Tuple[int, int, int]:
    """(msg_type, req_id, body_len) — the v2-shaped view; flags (and
    the trace envelope they announce) are handled by the callers that
    opt in via ``decode_header_ex``."""
    return decode_header_ex(hdr, off)[:3]


def _recv_exact(sock, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionClosed("socket closed")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def send_frame(sock, msg_type: int, obj: Any, req_id: int = 0) -> None:
    sock.sendall(encode_frame(msg_type, obj, req_id))


def recv_frame(sock) -> Tuple[int, int, Any]:
    msg_type, req_id, body_len, flags = \
        decode_header_ex(_recv_exact(sock, HEADER_LEN))
    if flags & FLAG_TRACE:
        _recv_exact(sock, TRACE_LEN)
    if flags & FLAG_MAPV:
        _recv_exact(sock, MAPV_LEN)
    body = _recv_exact(sock, body_len) if body_len else b""
    return msg_type, req_id, unpack(body)


class FrameReader:
    """Zero-copy buffered frame parser over a socket.

    Pipelined peers put many small frames on the wire back-to-back; one
    ``recv_into`` here can pull dozens of them into the rolling buffer,
    and the parser then hands them out without another syscall (or
    another GIL hand-off — on a busy multiplexed connection the
    scheduling churn, not the copy, is what batching amortizes).

    Frames are decoded *in place*: the header via ``decode_header`` on a
    memoryview and the body via ``_unpack_from`` straight out of the
    buffer, so the only per-frame copies are the payload ``bytes``
    objects the decoded value tree actually hands out (a block payload
    in a ``fetch_blocks`` reply is materialized exactly once, not
    header-copy + body-copy + bin-copy as the old reader did).
    ``frames`` / ``body_bytes`` / ``bytes_copied`` count that:
    copies-per-frame == bytes_copied / body_bytes <= 1.

    ``fill`` accepts recv flags (e.g. ``MSG_DONTWAIT``) and returns
    ``None`` on would-block, which lets non-blocking event loops and
    opportunistic drains share the same reader. ``pending()`` tells a
    server loop whether more complete frames are already buffered, which
    is the signal for coalescing replies before flushing."""

    __slots__ = ("sock", "_buf", "_head", "_tail", "frames",
                 "body_bytes", "_stats", "_sinks", "last_trace",
                 "last_mapv")

    INIT_BUF = 1 << 16
    SHRINK_ABOVE = 4 << 20

    def __init__(self, sock=None):
        self.sock = sock
        self._buf = bytearray(self.INIT_BUF)
        self._head = 0
        self._tail = 0
        self.frames = 0
        self.body_bytes = 0
        self._stats = [0, 0]
        #: req_id -> sink callable for the NEXT frame carrying that id.
        #: A sink receives each bin payload length and may return a
        #: writable memoryview of exactly that length (payload lands
        #: there, no bytes object is built) or None (normal copy-out).
        self._sinks: Dict[int, Any] = {}
        #: (trace_id, span_id) from the last frame's envelope, or None
        self.last_trace: Optional[Tuple[int, int]] = None
        #: highest shard-map version any frame has advertised, or None
        self.last_mapv: Optional[int] = None

    @property
    def bytes_copied(self) -> int:
        """Payload (bin) bytes materialized out of the buffer."""
        return self._stats[0]

    @property
    def bytes_sunk(self) -> int:
        """Payload (bin) bytes decoded straight into caller-provided
        destinations (arena / tensor memory) instead of fresh ``bytes``
        objects — the zero-copy half of the counter discipline."""
        return self._stats[1]

    def set_sink(self, req_id: int, sink) -> None:
        """Arm ``sink`` for the next frame whose header carries
        ``req_id``. Consumed by that one frame; the caller must re-arm
        per request. Sinks do not survive reader replacement (redial):
        callers fall back to the plain-bytes path automatically."""
        self._sinks[req_id] = sink

    def clear_sink(self, req_id: int) -> None:
        self._sinks.pop(req_id, None)

    def _reclaim(self) -> None:
        buf = self._buf
        avail = self._tail - self._head
        if self._head:
            buf[:avail] = buf[self._head:self._tail]
            self._head, self._tail = 0, avail
        if len(buf) - self._tail < (1 << 16):
            buf += bytes(max(len(buf), 1 << 16))

    def fill(self, flags: int = 0) -> Optional[int]:
        """recv_into the buffer. Returns the byte count (0 == EOF) or
        ``None`` if ``flags`` made the call would-block."""
        if self._head == self._tail:
            self._head = self._tail = 0
            if len(self._buf) > self.SHRINK_ABOVE:
                self._buf = bytearray(self.INIT_BUF)
        if len(self._buf) - self._tail < 4096:
            self._reclaim()
        view = memoryview(self._buf)[self._tail:]
        try:
            n = self.sock.recv_into(view, 0, flags)
        except (BlockingIOError, InterruptedError):
            return None
        finally:
            view.release()
        self._tail += n
        return n

    def next_frame(self) -> Optional[Tuple[int, int, Any]]:
        """Parse one complete frame from the buffer, or ``None`` if a
        full frame has not arrived yet. No syscalls."""
        head = self._head
        avail = self._tail - head
        if avail < HEADER_LEN:
            return None
        mv = memoryview(self._buf)
        try:
            msg_type, req_id, body_len, flags = decode_header_ex(mv, head)
            body_at = head + HEADER_LEN
            env_len = (TRACE_LEN if flags & FLAG_TRACE else 0) \
                + (MAPV_LEN if flags & FLAG_MAPV else 0)
            if avail < HEADER_LEN + env_len:
                return None
            trace = None
            if flags & FLAG_TRACE:
                trace = _TRACE.unpack_from(mv, body_at)
                body_at += TRACE_LEN
            mapv = None
            if flags & FLAG_MAPV:
                mapv = _MAPV.unpack_from(mv, body_at)[0]
                body_at += MAPV_LEN
            end = body_at + body_len
            if self._tail < end:
                return None
            sink = self._sinks.pop(req_id, None) if self._sinks else None
            obj, off = _unpack_from(mv[:end], body_at, self._stats, sink)
            if off != end:
                raise WireError(
                    f"{end - off} trailing byte(s) after frame body"
                )
        finally:
            mv.release()
        self.last_trace = trace
        if mapv is not None and (self.last_mapv is None
                                 or mapv > self.last_mapv):
            self.last_mapv = mapv
        self._head = end
        if self._head == self._tail:
            self._head = self._tail = 0
        self.frames += 1
        self.body_bytes += body_len
        return msg_type, req_id, obj

    def recv_frame(self) -> Tuple[int, int, Any]:
        while True:
            frame = self.next_frame()
            if frame is not None:
                return frame
            if self.fill() == 0:
                raise ConnectionClosed("socket closed")

    def pending(self) -> bool:
        """A complete frame is already buffered (no syscall needed)."""
        avail = self._tail - self._head
        if avail < HEADER_LEN:
            return False
        _, _, body_len, flags = decode_header_ex(self._buf, self._head)
        need = HEADER_LEN + body_len
        if flags & FLAG_TRACE:
            need += TRACE_LEN
        if flags & FLAG_MAPV:
            need += MAPV_LEN
        return avail >= need


class SendQueue:
    """Scatter-gather output queue for one connection.

    Frames are encoded straight into a pooled ``bytearray`` (via the
    same reserve-header / pack-body / patch-header scheme as
    ``encode_frame_into``), except that large ``bytes`` payloads —
    block data in ``fetch_blocks`` / ``begin`` replies — are NOT copied
    into the buffer: the buffer is closed and the payload object itself
    rides as its own segment. ``flush`` hands the segment list to
    ``socket.sendmsg``, so a burst of replies leaves in one syscall
    with zero copies of the block payloads, and partial sends on a
    non-blocking socket resume at ``_off`` into the head segment."""

    __slots__ = ("segs", "size", "_open", "_spare", "_off")

    IOV_CAP = 64

    def __init__(self):
        self.segs: List[Any] = []
        self.size = 0          # unsent bytes across all segments
        self._open = None      # bytearray currently accepting encodes
        self._spare = None     # drained buffer pooled for reuse
        self._off = 0          # sent offset into segs[0]

    def _cur(self) -> bytearray:
        cur = self._open
        if cur is None:
            cur = self._spare if self._spare is not None else bytearray()
            self._spare = None
            self._open = cur
            self.segs.append(cur)
        return cur

    def put_frame(self, msg_type: int, obj: Any, req_id: int = 0,
                  mapv: Optional[int] = None) -> None:
        hdr_buf = self._cur()
        hdr_at = len(hdr_buf)
        hdr_buf += _HDR_PAD
        self.size += HEADER_LEN
        flags = 0
        if mapv is not None:
            hdr_buf += _MAPV.pack(mapv)
            self.size += MAPV_LEN
            flags = FLAG_MAPV
        size0 = self.size
        self._pack(obj)
        _HEADER.pack_into(hdr_buf, hdr_at, MAGIC, VERSION, msg_type, flags,
                          req_id, self.size - size0)

    def _pack(self, obj: Any) -> None:
        if isinstance(obj, (bytes, bytearray, memoryview)) \
                and len(obj) >= SPILL_MIN:
            cur = self._cur()
            n0 = len(cur)
            _pack_bin_header(len(obj), cur)
            payload = obj if type(obj) is bytes else bytes(obj)
            self.size += len(cur) - n0 + len(payload)
            self._open = None
            self.segs.append(payload)
        elif type(obj) is list:
            cur = self._cur()
            n0 = len(cur)
            _pack_array_header(len(obj), cur)
            self.size += len(cur) - n0
            for item in obj:
                self._pack(item)
        elif type(obj) is dict:
            cur = self._cur()
            n0 = len(cur)
            _pack_map_header(len(obj), cur)
            self.size += len(cur) - n0
            for k, v in obj.items():
                self._pack(k)
                self._pack(v)
        else:
            cur = self._cur()
            n0 = len(cur)
            _pack_into(obj, cur)
            self.size += len(cur) - n0

    def flush(self, sock) -> bool:
        """Send as much as the socket accepts without blocking; returns
        True when the queue fully drained."""
        while self.size:
            iov = []
            off = self._off
            for seg in self.segs[:self.IOV_CAP]:
                iov.append(memoryview(seg)[off:] if off else seg)
                off = 0
            try:
                n = sock.sendmsg(iov)
            except (BlockingIOError, InterruptedError):
                return False
            finally:
                for v in iov:
                    if isinstance(v, memoryview):
                        v.release()
            if n <= 0:
                return False
            self.size -= n
            self._advance(n)
        return True

    def _advance(self, n: int) -> None:
        while n:
            seg = self.segs[0]
            rem = len(seg) - self._off
            if n < rem:
                self._off += n
                return
            n -= rem
            self.segs.pop(0)
            self._off = 0
            if seg is self._open:
                self._open = None
                del seg[:]
                self._spare = seg


# --------------------------------------------------------------------------- #
# dataclass <-> value-tree conversions
# --------------------------------------------------------------------------- #
def payload_to_obj(p) -> Dict[str, Any]:
    return {
        "rt": p.read_ts,
        "r": [(r.key, r.version) for r in p.reads],
        "w": [(w.key, [tuple(pt) for pt in w.patches]) for w in p.writes],
        "p": [(pr.file_id, pr.kind.value, pr.value) for pr in p.predicates],
        "mu": dict(p.meta_updates),
        "nu": dict(p.name_updates),
        "nr": dict(p.name_reads),
        "mr": dict(p.meta_reads),
        "ro": p.read_only,
    }


def payload_from_obj(o: Dict[str, Any]):
    from repro.core.backend import TxnPayload  # avoid import cycle at top

    return TxnPayload(
        read_ts=o["rt"],
        reads=[ReadRecord(tuple(k), v) for k, v in o["r"]],
        writes=[
            WriteRecord(tuple(k), [tuple(pt) for pt in pts])
            for k, pts in o["w"]
        ],
        predicates=[
            LengthPredicate(fid, PredicateKind(kind), val)
            for fid, kind, val in o["p"]
        ],
        meta_updates=dict(o["mu"]),
        name_updates=dict(o["nu"]),
        name_reads=dict(o["nr"]),
        meta_reads=dict(o["mr"]),
        read_only=o["ro"],
    )


def begin_reply_to_obj(r) -> Dict[str, Any]:
    # "u" values are lists, not tuples: a SendQueue packs lists
    # incrementally, so a large pushed block rides as its own
    # scatter-gather segment instead of being copied (tuples travel in
    # an ext envelope whose length must be known upfront). The decoder
    # accepts either shape.
    return {
        "rt": r.read_ts,
        "u": {k: [ts, data] for k, (ts, data) in r.updates.items()},
        "i": list(r.invalidations),
        "fi": list(r.file_invalidations),
    }


def begin_reply_from_obj(o: Dict[str, Any]):
    from repro.core.backend import BeginReply

    return BeginReply(
        read_ts=o["rt"],
        updates={tuple(k): (ts, data) for k, (ts, data) in o["u"].items()},
        invalidations=[tuple(k) for k in o["i"]],
        file_invalidations=list(o["fi"]),
    )


def commit_reply_to_obj(r) -> Dict[str, Any]:
    o = {"ts": r.ts, "bv": dict(r.block_versions)}
    slot_ts = getattr(r, "slot_ts", None)
    if slot_ts:
        # per-slot commit timestamps, so a cluster coordinator proxying
        # the commit can advance its applied-vector floor (absent for
        # plain backends — old clients never see the key)
        o["st"] = dict(slot_ts)
    return o


def commit_reply_from_obj(o: Dict[str, Any]):
    from repro.core.api import CommitReply

    return CommitReply(
        ts=o["ts"], block_versions={tuple(k): v for k, v in o["bv"].items()},
        slot_ts={int(k): v for k, v in (o.get("st") or {}).items()},
    )


def metas_to_obj(entries) -> List[Any]:
    """Batch fetch_metas reply: None (never seen) or
    (ver, length, exists, kind, mtime_ts) — kind and the mtime commit
    timestamp travel with the meta so stat is honest over the wire."""
    return [
        None
        if e is None
        else (e[0], e[1].length, e[1].exists, e[1].kind, e[1].mtime_ts)
        for e in entries
    ]


def metas_from_obj(obj) -> List[Any]:
    from repro.core.blockstore import FileMeta  # avoid import cycle at top

    return [
        None if e is None else (e[0], FileMeta(e[1], e[2], e[3], e[4]))
        for e in obj
    ]


def stats_to_obj(stats) -> Dict[str, Any]:
    d = asdict(stats)
    extra = getattr(stats, "extra", None)
    if extra:
        d.update(extra)
    return d


def stats_from_obj(o: Dict[str, Any]):
    """Forward-compatible: keys a newer server sends that this client's
    ``BackendStats`` does not know are kept on ``stats.extra`` (and
    ``stats_to_obj`` merges them back), instead of crashing the scrape.
    That is what lets an old client read a new server's T_STATS reply —
    e.g. the ``metrics`` registry snapshot rides as an extra key."""
    from repro.core.backend import BackendStats

    known = {f.name for f in dataclasses.fields(BackendStats)}
    s = BackendStats(**{k: v for k, v in o.items() if k in known})
    extra = {k: v for k, v in o.items() if k not in known}
    if extra:
        s.extra = extra
    return s


# --------------------------------------------------------------------------- #
# exceptions over the wire
# --------------------------------------------------------------------------- #
def _conflict_keys_to_obj(keys) -> List[Any]:
    out: List[Any] = []
    for item in keys:
        try:
            tag, detail = item
        except (TypeError, ValueError):
            out.append(("opaque", repr(item)))
            continue
        if isinstance(detail, LengthPredicate):
            detail = (detail.file_id, detail.kind.value, detail.value)
            tag = "predicate"
        out.append((tag, detail))
    return out


def _conflict_keys_from_obj(obj) -> List[Any]:
    out: List[Any] = []
    for tag, detail in obj:
        if tag == "predicate":
            fid, kind, val = detail
            detail = LengthPredicate(fid, PredicateKind(kind), val)
        out.append((tag, detail))
    return out


def _conflict_detail_to_obj(detail) -> List[Any]:
    # explainability entries are flat dicts of wire-safe scalars/tuples
    # ({"tag","key","shard","winner"}); pass through with a repr guard
    out: List[Any] = []
    for d in detail:
        try:
            out.append({str(k): v if isinstance(
                v, (int, str, tuple, bytes, type(None))) else repr(v)
                for k, v in d.items()})
        except AttributeError:
            out.append({"tag": "opaque", "key": repr(d)})
    return out


def exception_to_obj(exc: BaseException) -> Dict[str, Any]:
    extra = None
    if isinstance(exc, Conflict):
        extra = _conflict_keys_to_obj(exc.keys)
        detail = getattr(exc, "detail", None)
        if detail:
            extra = {"k": extra, "d": _conflict_detail_to_obj(detail)}
    return {"t": type(exc).__name__, "m": str(exc), "x": extra}


def exception_from_obj(o: Dict[str, Any]) -> BaseException:
    from repro.core.blockstore import SnapshotTooOld
    from repro.core.wal import WalFailed

    etype, msg, extra = o["t"], o["m"], o["x"]
    if etype == "Conflict":
        detail = None
        if isinstance(extra, dict):        # enriched envelope (PR 7+)
            detail = [
                {k: tuple(v) if isinstance(v, list) else v
                 for k, v in d.items()}
                for d in extra.get("d") or []
            ]
            extra = extra.get("k")
        return Conflict(msg, _conflict_keys_from_obj(extra or []),
                        detail=detail)
    table = {
        "NotFound": NotFound,
        "Exists": Exists,
        "TxnStateError": TxnStateError,
        "SnapshotTooOld": SnapshotTooOld,
        "StaleEpoch": StaleEpoch,
        "StaleShardMap": StaleShardMap,
        "PermissionDenied": PermissionDenied,
        # a poisoned durable log: the commit was NOT acked and the server
        # will fail every further commit until it restarts and recovers
        "WalFailed": WalFailed,
        "ValueError": ValueError,
        "KeyError": KeyError,
    }
    cls = table.get(etype)
    if cls is not None:
        return cls(msg)
    return RemoteError(f"{etype}: {msg}")
