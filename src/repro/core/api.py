"""Backend transport API — the contract between clients and any backend.

The paper's prototype wires the Local Server directly to one in-process
monolithic backend. To grow past that (sharded backends, networked
transports), every client-visible operation is pinned down here as an
abstract ``BackendAPI``:

  begin / sync_file / fetch_block / fetch_meta / lookup / listdir /
  commit / alloc_file_id

plus a small *timestamp algebra* (``zero_ts`` / ``ts_geq`` /
``snapshot_cache_ok``) so clients never interpret sync timestamps
themselves: the monolithic backend uses scalar timestamps, the sharded
backend a per-shard vector, and client code works unchanged over both.

Transport concerns live in wrappers, not in the backend:
``LatencyInjector`` charges one simulated network round trip per
client-visible call (replacing the old ad-hoc ``rpc_latency_s`` sleeps
inside ``BackendService``). The real networked transport is
``repro.core.remote.RemoteBackend`` — the same calls serialized over a
socket to ``repro.core.server.BackendServer`` (wire format in
``repro.core.wire``, durable commit log in ``repro.core.wal``; see
docs/transport.md). ``bench_remote`` calibrates the injector's simulated
RTT against the real thing.
"""
from __future__ import annotations

import time
from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Set, Tuple

from repro.core.types import (
    BlockKey,
    CachePolicy,
    FileId,
    SyncTimestamp,
    Timestamp,
)

if TYPE_CHECKING:  # avoid an import cycle with backend.py at runtime
    from repro.core.backend import BeginReply


@dataclass
class CommitReply:
    """Result of a successful commit.

    ``ts``             — backend-assigned commit token, one uniform kind
                         per backend: the commit timestamp under the
                         monolithic backend (its read timestamp for
                         read-only commits), the coordinator's global
                         scalar timestamp under the sharded backend.
                         Monotone across a client's sequential commits;
                         informational only — never fed back into reads.
    ``block_versions`` — shard-local version assigned to each written
                         block, so the client can write committed data
                         through into its cache with the exact version
                         that commit validation will later compare.
    """

    ts: SyncTimestamp
    block_versions: Dict[BlockKey, Timestamp] = field(default_factory=dict)


class BackendAPI(ABC):
    """Abstract transactional backend (paper §4.1's Backend Service)."""

    # Implementations expose these (attribute or property):
    block_size: int
    policy: CachePolicy

    @property
    def zero_ts(self) -> SyncTimestamp:
        """The sync timestamp of a brand-new client (never synced)."""
        return 0

    # ------------------------- timestamp algebra ---------------------- #
    def ts_geq(self, a: SyncTimestamp, b: SyncTimestamp) -> bool:
        """a >= b, componentwise for vector timestamps."""
        return a >= b  # type: ignore[operator]

    def snapshot_cache_ok(
        self,
        key: BlockKey,
        version: Timestamp,
        at_ts: SyncTimestamp,
        last_sync_ts: SyncTimestamp,
    ) -> bool:
        """May a cached entry (``version``) serve a snapshot read at
        ``at_ts``?  Only if it is provably the latest version <= at_ts,
        i.e. the cache has been synced past the snapshot point."""
        return version <= at_ts and last_sync_ts >= at_ts  # type: ignore

    # ----------------------------- RPCs ------------------------------- #
    @abstractmethod
    def begin(
        self,
        last_sync_ts: SyncTimestamp,
        cached_keys: Optional[Set[BlockKey]] = None,
        policy: Optional[CachePolicy] = None,
    ) -> "BeginReply": ...

    @abstractmethod
    def sync_file(
        self, fid: FileId, known_versions: Dict[BlockKey, Timestamp]
    ) -> Dict[BlockKey, Tuple[Timestamp, bytes]]: ...

    @abstractmethod
    def fetch_block(
        self, key: BlockKey, at_ts: Optional[SyncTimestamp] = None
    ) -> Tuple[Timestamp, bytes]: ...

    @abstractmethod
    def fetch_meta(self, fid: FileId, at_ts: Optional[SyncTimestamp] = None): ...

    @abstractmethod
    def lookup(
        self, path: str, at_ts: Optional[SyncTimestamp] = None
    ) -> Tuple[Timestamp, Optional[FileId]]:
        """(observed name version, bound file id or None), atomically."""

    @abstractmethod
    def listdir(
        self, prefix: str, at_ts: Optional[SyncTimestamp] = None
    ) -> List[Tuple[str, Timestamp, Optional[FileId]]]:
        """Direct children of ``prefix`` as (full_path, version, fid);
        unbound tombstones are included (fid None) so callers can record
        the observed absence."""

    @abstractmethod
    def commit(self, payload) -> CommitReply:
        """OCC-validate and apply a TxnPayload; raises Conflict."""

    @abstractmethod
    def alloc_file_id(self) -> FileId: ...


#: calls that cost one network round trip in the paper's EC2 deployment;
#: lookup/fetch_meta/listdir piggyback on other messages there.
DEFAULT_CHARGED_CALLS = ("begin", "sync_file", "fetch_block", "commit")


class LatencyInjector(BackendAPI):
    """Transport wrapper charging a simulated RTT per client-visible call.

    Wrap any ``BackendAPI`` (monolithic or sharded) to model a networked
    deployment::

        be = LatencyInjector(BackendService(...), rpc_latency_s=100e-6)
    """

    def __init__(
        self,
        inner: BackendAPI,
        rpc_latency_s: float,
        charged_calls: Tuple[str, ...] = DEFAULT_CHARGED_CALLS,
    ):
        self.inner = inner
        self.rpc_latency_s = rpc_latency_s
        self.charged_calls = frozenset(charged_calls)

    def _rpc(self, call: str) -> None:
        if self.rpc_latency_s and call in self.charged_calls:
            time.sleep(self.rpc_latency_s)

    # -------------------------- delegation ---------------------------- #
    @property
    def block_size(self) -> int:
        return self.inner.block_size

    @property
    def policy(self) -> CachePolicy:
        return self.inner.policy

    @property
    def zero_ts(self) -> SyncTimestamp:
        return self.inner.zero_ts

    @property
    def stats(self):
        return self.inner.stats

    @property
    def latest_ts(self):
        return self.inner.latest_ts

    def ts_geq(self, a, b) -> bool:
        return self.inner.ts_geq(a, b)

    def snapshot_cache_ok(self, key, version, at_ts, last_sync_ts) -> bool:
        return self.inner.snapshot_cache_ok(key, version, at_ts, last_sync_ts)

    def begin(self, last_sync_ts, cached_keys=None, policy=None):
        self._rpc("begin")
        return self.inner.begin(last_sync_ts, cached_keys, policy)

    def sync_file(self, fid, known_versions):
        self._rpc("sync_file")
        return self.inner.sync_file(fid, known_versions)

    def fetch_block(self, key, at_ts=None):
        self._rpc("fetch_block")
        return self.inner.fetch_block(key, at_ts)

    def fetch_meta(self, fid, at_ts=None):
        self._rpc("fetch_meta")
        return self.inner.fetch_meta(fid, at_ts)

    def lookup(self, path, at_ts=None):
        self._rpc("lookup")
        return self.inner.lookup(path, at_ts)

    def listdir(self, prefix, at_ts=None):
        self._rpc("listdir")
        return self.inner.listdir(prefix, at_ts)

    def commit(self, payload) -> CommitReply:
        self._rpc("commit")
        return self.inner.commit(payload)

    def alloc_file_id(self) -> FileId:
        self._rpc("alloc_file_id")
        return self.inner.alloc_file_id()
