"""Backend transport API — the contract between clients and any backend.

The paper's prototype wires the Local Server directly to one in-process
monolithic backend. To grow past that (sharded backends, networked
transports), every client-visible operation is pinned down here as an
abstract ``BackendAPI``.

**Batch-first.** The abstract surface is *plural*: backends implement

  begin / sync_files / fetch_blocks / fetch_metas / lookup_many /
  listdir / commit / alloc_file_id

and the scalar forms the original API shipped with (``fetch_block``,
``fetch_meta``, ``lookup``, ``sync_file``) are concrete shims over the
batch core defined once, here. A backend therefore implements ONE
surface; clients may call either form, and a batch is one logical round
trip on every transport (`LatencyInjector` charges it as one, the wire
ships it as one frame, `ShardedBackend` fans it out and merges
server-side exactly like ``begin``).

**Futures.** ``submit(op, *args) -> BackendFuture`` is the pipelining
hook: callers get a completion handle instead of blocking the thread.
The default implementation runs the call inline (correct for every
in-process backend); ``RemoteBackend`` overrides it to put many requests
in flight on one multiplexed connection, matching request ids to
out-of-order replies (see docs/api.md and docs/transport.md).

A small *timestamp algebra* (``zero_ts`` / ``ts_geq`` /
``snapshot_cache_ok``) rides along so clients never interpret sync
timestamps themselves: the monolithic backend uses scalar timestamps,
the sharded backend a per-shard vector, and client code works unchanged
over both.

Transport concerns live in wrappers, not in the backend:
``LatencyInjector`` charges one simulated network round trip per
client-visible call — batch or scalar — replacing the old ad-hoc
``rpc_latency_s`` sleeps inside ``BackendService``. The real networked
transport is ``repro.core.remote.RemoteBackend`` — the same calls
serialized over a socket to ``repro.core.server.BackendServer`` (wire
format in ``repro.core.wire``, durable commit log in ``repro.core.wal``;
see docs/transport.md). ``bench_remote`` calibrates the injector's
simulated RTT against the real thing.
"""
from __future__ import annotations

import threading
import time
from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Set, Tuple

from repro.core.types import (
    BlockKey,
    CachePolicy,
    FileId,
    NotFound,
    SyncTimestamp,
    Timestamp,
)

if TYPE_CHECKING:  # avoid an import cycle with backend.py at runtime
    from repro.core.backend import BeginReply


@dataclass
class CommitReply:
    """Result of a successful commit.

    ``ts``             — backend-assigned commit token, one uniform kind
                         per backend: the commit timestamp under the
                         monolithic backend (its read timestamp for
                         read-only commits), the coordinator's global
                         scalar timestamp under the sharded backend.
                         Monotone across a client's sequential commits;
                         informational only — never fed back into reads.
    ``block_versions`` — shard-local version assigned to each written
                         block, so the client can write committed data
                         through into its cache with the exact version
                         that commit validation will later compare.
    ``slot_ts``        — per-slot commit timestamps the commit advanced
                         (sharded backends only; empty elsewhere). A
                         cluster coordinator proxying the commit uses
                         these to advance its applied-vector view.
    """

    ts: SyncTimestamp
    block_versions: Dict[BlockKey, Timestamp] = field(default_factory=dict)
    slot_ts: Dict[int, Timestamp] = field(default_factory=dict)


class BackendFuture:
    """Completion handle for a pipelined backend call.

    A minimal future: ``result()`` blocks until the value (or error)
    arrives, ``done()`` polls. Produced completed by the default inline
    ``BackendAPI.submit`` and resolved asynchronously by transports that
    really pipeline (``RemoteBackend``'s reader thread).

    ``_flush`` is the transport's lazy-send hook: a pipelining client may
    buffer the request frame instead of paying a syscall (and a GIL
    hand-off) per submit; the first consumer about to wait triggers one
    coalesced flush of everything buffered behind it.

    ``_wait`` is the transport's serial fast-path hook: called (if set)
    with ``(future, timeout)`` before parking on the event, it lets the
    waiting thread drive the transport's receive path itself — the
    common serial RPC then completes with zero extra thread wakeups
    instead of hopping through a dedicated reader thread."""

    __slots__ = ("_event", "_value", "_error", "_flush", "_wait", "_obs")

    def __init__(self) -> None:
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self._flush: Optional[Any] = None
        self._wait: Optional[Any] = None
        self._obs: Optional[Any] = None  # transport-stamped (t0_us, op, trace)

    def _ensure_sent(self) -> None:
        flush, self._flush = self._flush, None
        if flush is not None and not self._event.is_set():
            flush()

    # -- producer side ------------------------------------------------- #
    def set_result(self, value: Any) -> None:
        self._value = value
        self._event.set()

    def set_exception(self, exc: BaseException) -> None:
        self._error = exc
        self._event.set()

    @classmethod
    def completed(cls, value: Any) -> "BackendFuture":
        f = cls()
        f.set_result(value)
        return f

    @classmethod
    def failed(cls, exc: BaseException) -> "BackendFuture":
        f = cls()
        f.set_exception(exc)
        return f

    # -- consumer side ------------------------------------------------- #
    def done(self) -> bool:
        if not self._event.is_set():
            self._ensure_sent()
            w = self._wait
            if w is not None and not self._event.is_set():
                w(self, 0)  # poll: nudge the transport, never block
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        self._ensure_sent()
        w = self._wait
        if w is not None and not self._event.is_set():
            w(self, timeout)
        if not self._event.wait(timeout):
            raise TimeoutError("backend call still in flight")
        if self._error is not None:
            raise self._error
        return self._value

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        self._ensure_sent()
        w = self._wait
        if w is not None and not self._event.is_set():
            w(self, timeout)
        if not self._event.wait(timeout):
            raise TimeoutError("backend call still in flight")
        return self._error


class BackendAPI(ABC):
    """Abstract transactional backend (paper §4.1's Backend Service)."""

    # Implementations expose these (attribute or property):
    block_size: int
    policy: CachePolicy

    @property
    def zero_ts(self) -> SyncTimestamp:
        """The sync timestamp of a brand-new client (never synced)."""
        return 0

    # ------------------------- timestamp algebra ---------------------- #
    def ts_geq(self, a: SyncTimestamp, b: SyncTimestamp) -> bool:
        """a >= b, componentwise for vector timestamps."""
        return a >= b  # type: ignore[operator]

    def snapshot_cache_ok(
        self,
        key: BlockKey,
        version: Timestamp,
        at_ts: SyncTimestamp,
        last_sync_ts: SyncTimestamp,
    ) -> bool:
        """May a cached entry (``version``) serve a snapshot read at
        ``at_ts``?  Only if it is provably the latest version <= at_ts,
        i.e. the cache has been synced past the snapshot point."""
        return version <= at_ts and last_sync_ts >= at_ts  # type: ignore

    # ----------------------- RPCs: batch core ------------------------- #
    @abstractmethod
    def begin(
        self,
        last_sync_ts: SyncTimestamp,
        cached_keys: Optional[Set[BlockKey]] = None,
        policy: Optional[CachePolicy] = None,
    ) -> "BeginReply": ...

    @abstractmethod
    def fetch_blocks(
        self, keys: List[BlockKey], at_ts: Optional[SyncTimestamp] = None
    ) -> List[Tuple[Timestamp, bytes]]:
        """Current (or snapshot) contents of ``keys``, one entry per key,
        in input order. One logical round trip regardless of len(keys)."""

    @abstractmethod
    def fetch_metas(
        self, fids: List[FileId], at_ts: Optional[SyncTimestamp] = None
    ) -> List[Optional[Tuple[Timestamp, Any]]]:
        """Per-fid ``(version, FileMeta)`` in input order; ``None`` for a
        file the backend has never seen (the scalar shim raises
        ``NotFound`` for those)."""

    @abstractmethod
    def lookup_many(
        self, paths: List[str], at_ts: Optional[SyncTimestamp] = None
    ) -> List[Tuple[Timestamp, Optional[FileId]]]:
        """(observed name version, bound file id or None) per path,
        atomically per entry, in input order."""

    @abstractmethod
    def sync_files(
        self, reqs: Dict[FileId, Dict[BlockKey, Timestamp]]
    ) -> Dict[FileId, Dict[BlockKey, Tuple[Timestamp, bytes]]]:
        """Bring several files' cached blocks current in one round trip:
        ``{fid: {key: known_version}} -> {fid: {key: (version, data)}}``
        (only entries newer than the known version are returned)."""

    @abstractmethod
    def listdir(
        self, prefix: str, at_ts: Optional[SyncTimestamp] = None
    ) -> List[Tuple[str, Timestamp, Optional[FileId]]]:
        """Direct children of ``prefix`` as (full_path, version, fid);
        unbound tombstones are included (fid None) so callers can record
        the observed absence."""

    @abstractmethod
    def commit(self, payload) -> CommitReply:
        """OCC-validate and apply a TxnPayload; raises Conflict."""

    @abstractmethod
    def alloc_file_id(self) -> FileId: ...

    # ---------------------- zero-copy variant ------------------------- #
    def fetch_blocks_into(
        self,
        keys: List[BlockKey],
        at_ts: Optional[SyncTimestamp],
        sink,
    ) -> List[Tuple[Timestamp, Any]]:
        """``fetch_blocks`` that lands payloads in caller memory.

        ``sink(i, nbytes)`` is asked, per result index, for a writable
        memoryview of exactly ``nbytes``; when it returns one the
        payload is placed there and the result entry's data IS that
        view, otherwise the entry is the usual ``bytes``. The default
        shim copies once out of ``fetch_blocks`` (in-process backends
        hand out their interned store bytes, so this is the single
        materializing copy); ``RemoteBackend`` overrides it to decode
        straight out of the ``recv_into`` rolling buffer into the sink
        destination — zero bytes objects on the block hot path."""
        out: List[Tuple[Timestamp, Any]] = []
        for i, (ver, data) in enumerate(self.fetch_blocks(keys, at_ts)):
            dst = sink(i, len(data))
            if dst is not None:
                dst[:] = data
                out.append((ver, dst))
            else:
                out.append((ver, data))
        return out

    # ------------------- scalar shims over the batch core ------------- #
    def fetch_block(
        self, key: BlockKey, at_ts: Optional[SyncTimestamp] = None
    ) -> Tuple[Timestamp, bytes]:
        return self.fetch_blocks([key], at_ts)[0]

    def fetch_meta(self, fid: FileId, at_ts: Optional[SyncTimestamp] = None):
        out = self.fetch_metas([fid], at_ts)[0]
        if out is None:
            raise NotFound(f"file {fid}")
        return out

    def lookup(
        self, path: str, at_ts: Optional[SyncTimestamp] = None
    ) -> Tuple[Timestamp, Optional[FileId]]:
        return self.lookup_many([path], at_ts)[0]

    def sync_file(
        self, fid: FileId, known_versions: Dict[BlockKey, Timestamp]
    ) -> Dict[BlockKey, Tuple[Timestamp, bytes]]:
        return self.sync_files({fid: dict(known_versions)}).get(fid, {})

    # --------------------------- pipelining --------------------------- #
    def submit(self, op: str, *args, **kwargs) -> BackendFuture:
        """Asynchronous form of any RPC: returns a ``BackendFuture``
        instead of blocking. ``op`` names a method on this API
        (``"fetch_blocks"``, ``"commit"``, ...). The default executes
        inline — in-process backends have no round trip to hide;
        ``RemoteBackend`` overrides this with true request-id pipelining."""
        fut = BackendFuture()
        try:
            fut.set_result(getattr(self, op)(*args, **kwargs))
        except Exception as e:
            fut.set_exception(e)
        return fut


#: calls that cost one network round trip in the paper's EC2 deployment;
#: lookup/fetch_meta/listdir piggyback on other messages there. A batch
#: call is ONE round trip no matter how many items it carries.
DEFAULT_CHARGED_CALLS = (
    "begin",
    "sync_file",
    "sync_files",
    "fetch_block",
    "fetch_blocks",
    "commit",
)


class LatencyInjector(BackendAPI):
    """Transport wrapper charging a simulated RTT per client-visible call.

    Wrap any ``BackendAPI`` (monolithic or sharded) to model a networked
    deployment::

        be = LatencyInjector(BackendService(...), rpc_latency_s=100e-6)

    Batch calls are charged as ONE round trip — the whole point of the
    batch-first surface — so mono / sharded / remote backends stay
    comparable under the simulation.
    """

    def __init__(
        self,
        inner: BackendAPI,
        rpc_latency_s: float,
        charged_calls: Tuple[str, ...] = DEFAULT_CHARGED_CALLS,
    ):
        self.inner = inner
        self.rpc_latency_s = rpc_latency_s
        self.charged_calls = frozenset(charged_calls)

    def _rpc(self, call: str) -> None:
        if self.rpc_latency_s and call in self.charged_calls:
            time.sleep(self.rpc_latency_s)

    # -------------------------- delegation ---------------------------- #
    @property
    def block_size(self) -> int:
        return self.inner.block_size

    @property
    def policy(self) -> CachePolicy:
        return self.inner.policy

    @property
    def zero_ts(self) -> SyncTimestamp:
        return self.inner.zero_ts

    @property
    def stats(self):
        return self.inner.stats

    @property
    def latest_ts(self):
        return self.inner.latest_ts

    def ts_geq(self, a, b) -> bool:
        return self.inner.ts_geq(a, b)

    def snapshot_cache_ok(self, key, version, at_ts, last_sync_ts) -> bool:
        return self.inner.snapshot_cache_ok(key, version, at_ts, last_sync_ts)

    def begin(self, last_sync_ts, cached_keys=None, policy=None):
        self._rpc("begin")
        return self.inner.begin(last_sync_ts, cached_keys, policy)

    def fetch_blocks(self, keys, at_ts=None):
        self._rpc("fetch_blocks")
        return self.inner.fetch_blocks(keys, at_ts)

    def fetch_metas(self, fids, at_ts=None):
        self._rpc("fetch_meta")
        return self.inner.fetch_metas(fids, at_ts)

    def lookup_many(self, paths, at_ts=None):
        self._rpc("lookup")
        return self.inner.lookup_many(paths, at_ts)

    def sync_files(self, reqs):
        self._rpc("sync_files")
        return self.inner.sync_files(reqs)

    def fetch_block(self, key, at_ts=None):
        self._rpc("fetch_block")
        return self.inner.fetch_block(key, at_ts)

    def fetch_meta(self, fid, at_ts=None):
        self._rpc("fetch_meta")
        return self.inner.fetch_meta(fid, at_ts)

    def lookup(self, path, at_ts=None):
        self._rpc("lookup")
        return self.inner.lookup(path, at_ts)

    def sync_file(self, fid, known_versions):
        self._rpc("sync_file")
        return self.inner.sync_file(fid, known_versions)

    def listdir(self, prefix, at_ts=None):
        self._rpc("listdir")
        return self.inner.listdir(prefix, at_ts)

    def commit(self, payload) -> CommitReply:
        self._rpc("commit")
        return self.inner.commit(payload)

    def alloc_file_id(self) -> FileId:
        self._rpc("alloc_file_id")
        return self.inner.alloc_file_id()
