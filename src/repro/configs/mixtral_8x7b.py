"""Mixtral-8x7B — MoE: 8 experts, top-2, sliding-window attention [arXiv:2401.04088; hf]."""
from repro.configs.base import ModelConfig, register

MIXTRAL_8X7B = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32_000,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=14336,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088; hf",
))
