"""Qwen3-30B-A3B — MoE: 128 experts, top-8, QK-norm [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, register

QWEN3_MOE_30B_A3B = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151_936,
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=768,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
