"""Hymba-1.5B — hybrid: parallel attention + mamba heads [arXiv:2411.13676; hf].

Simplification recorded in DESIGN.md: all layers use the hybrid block with
sliding-window attention (the published model keeps a few global-attention
layers); head fusion is the mean of the attention and SSM branches after
per-branch normalization.
"""
from repro.configs.base import ModelConfig, register

HYMBA_1_5B = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    ssm_state=16,
    ssm_conv=4,
    d_inner=3200,
    dt_rank=100,
    sliding_window=2048,
    rope_theta=10_000.0,
    source="arXiv:2411.13676; hf",
))
