"""MusicGen-Large backbone — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only: the EnCodec frontend is a stub; ``input_specs()`` provides
token ids over the 2048-entry codebook. (kv=32 == MHA.)
"""
from repro.configs.base import ModelConfig, register

MUSICGEN_LARGE = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    audio_tokens=True,
    rope_theta=10_000.0,
    source="arXiv:2306.05284; hf",
))
