"""Architecture config system.

Every assigned architecture is a frozen ``ModelConfig``; every assigned
input-shape cell is a ``ShapeCell``. The dry-run, smoke tests, benchmarks and
launchers all key off this registry (``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    """A decoder-LM architecture (backbone only for audio/vlm)."""

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int          # query heads; 0 for attention-free archs
    num_kv_heads: int
    head_dim: int
    d_ff: int               # dense FFN width (per-expert width for MoE in moe_d_ff)
    vocab_size: int

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: int = 0
    dt_rank: int = 0

    # --- attention details ---
    sliding_window: int = 0     # 0 => full attention
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0

    # --- modality stubs ---
    vision_prefix: int = 0      # [vlm] precomputed patch embeddings prepended
    audio_tokens: bool = False  # [audio] tokens are EnCodec codes (stub frontend)

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    source: str = ""            # provenance tag, e.g. "arXiv:2407.14679; hf"

    # ------------------------------------------------------------------ #
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_attention(self) -> bool:
        return not self.attention_free

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context without O(S) full-softmax KV?

        SSM archs carry O(1) state; hybrid uses SWA+SSM; SWA archs have a
        bounded attention window.
        """
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model FLOPs)."""
        D, L = self.d_model, self.num_layers
        n = self.vocab_size * D  # embedding
        if not self.tie_embeddings:
            n += D * self.vocab_size  # lm head
        n += D  # final norm
        per_layer = 0
        if self.has_attention:
            per_layer += D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            if self.qkv_bias:
                per_layer += self.q_dim + 2 * self.kv_dim
            if self.qk_norm:
                per_layer += 2 * self.head_dim
        if self.has_ssm:
            di = self.d_inner
            per_layer += (
                D * 2 * di                      # in_proj
                + di * self.ssm_conv + di       # conv
                + di * (self.dt_rank + 2 * self.ssm_state)  # x_proj
                + self.dt_rank * di + di        # dt_proj
                + di * self.ssm_state + di      # A_log, D skip
                + di * D                        # out_proj
            )
        if self.is_moe:
            per_layer += D * self.num_experts  # router
            per_layer += self.num_experts * 3 * D * self.moe_d_ff
        elif self.d_ff > 0:
            per_layer += 3 * D * self.d_ff
        # norms: pre-mixer ln1, pre-ffn ln2, hybrid branch-fusion norms
        per_layer += D                          # ln1
        if self.is_moe or self.d_ff > 0:
            per_layer += D                      # ln2
        if self.family == "hybrid":
            per_layer += 2 * D                  # branch norms
        return n + L * per_layer

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: routed experts only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        moe_all = self.num_layers * self.num_experts * 3 * self.d_model * self.moe_d_ff
        moe_active = (
            self.num_layers * self.num_experts_per_tok * 3 * self.d_model * self.moe_d_ff
        )
        return full - moe_all + moe_active


@dataclass(frozen=True)
class ShapeCell:
    """An assigned input-shape cell."""

    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)
SHAPES_BY_NAME: Dict[str, ShapeCell] = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Whether (arch x shape) is a runnable cell; reason if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (see DESIGN.md)"
        )
    return True, ""


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}"
        ) from None


def list_configs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import for registration side effects
    from repro.configs import (  # noqa: F401
        deepseek_coder_33b,
        falcon_mamba_7b,
        granite_3_2b,
        hymba_1_5b,
        internvl2_76b,
        minitron_8b,
        mixtral_8x7b,
        musicgen_large,
        qwen2_1_5b,
        qwen3_moe_30b_a3b,
    )


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small: Dict[str, object] = dict(
        num_layers=2,
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        rope_theta=cfg.rope_theta,
    )
    if cfg.has_attention:
        small.update(num_heads=4, num_kv_heads=max(1, 4 * cfg.num_kv_heads // max(cfg.num_heads, 1)), head_dim=16)
    else:
        small.update(num_heads=0, num_kv_heads=0, head_dim=0)
    if cfg.is_moe:
        small.update(num_experts=4, num_experts_per_tok=min(2, cfg.num_experts_per_tok), moe_d_ff=32, d_ff=0)
    if cfg.has_ssm:
        small.update(d_inner=128, ssm_state=8, dt_rank=8, ssm_conv=cfg.ssm_conv)
    if cfg.sliding_window:
        small.update(sliding_window=32)
    if cfg.vision_prefix:
        small.update(vision_prefix=8)
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **small)  # type: ignore[arg-type]
