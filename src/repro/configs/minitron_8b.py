"""Minitron-8B — width/depth-pruned Nemotron-4 [arXiv:2407.14679; hf]."""
from repro.configs.base import ModelConfig, register

MINITRON_8B = register(ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256_000,
    rope_theta=10_000.0,
    source="arXiv:2407.14679; hf",
))
