"""InternVL2-76B backbone — InternLM2-style decoder [arXiv:2404.16821].

Backbone only: the InternViT frontend is a stub; ``input_specs()`` provides
``pixel_embeds`` — 256 precomputed patch embeddings prepended to the text
sequence (loss is masked over the vision prefix).
"""
from repro.configs.base import ModelConfig, register

INTERNVL2_76B = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    vision_prefix=256,
    rope_theta=500_000.0,
    source="arXiv:2404.16821; unverified",
))
