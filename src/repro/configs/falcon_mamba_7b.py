"""Falcon-Mamba-7B — attention-free mamba-1 architecture [arXiv:2410.05355]."""
from repro.configs.base import ModelConfig, register

FALCON_MAMBA_7B = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65_024,
    ssm_state=16,
    ssm_conv=4,
    d_inner=8192,
    dt_rank=256,
    source="arXiv:2410.05355; unverified",
))
