"""Transactional, block-granular checkpointing on the FaaSFS core.

Checkpoints are FaaSFS state objects:

  * ``save`` runs as ONE transaction — a checkpoint is atomically visible or
    not at all (no torn checkpoints on worker failure; the paper's atomic
    commit applied to training state),
  * consecutive saves ship only dirty blocks (delta checkpointing via the
    block-granular write sets — the paper's fine-grained cache updates),
  * ``restore`` pins a snapshot timestamp (multiversion read) so a restore
    is consistent even while training keeps committing,
  * a ``latest`` pointer file is atomically renamed into place (POSIX rename
    atomicity, validated by the namespace OCC checks).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.client import LocalServer
from repro.core.posix import FaaSFS, O_CREAT, O_TRUNC
from repro.core.retry import run_function
from repro.core.tensorstate import TensorStore, flatten_with_names, unflatten_like

PyTree = Any


@dataclass
class SaveInfo:
    step: int
    commit_ts: int
    bytes_total: int
    bytes_written: int
    blocks_written: int
    wall_s: float


class CheckpointManager:
    """Step-indexed checkpoints with delta commits and snapshot restores."""

    def __init__(
        self,
        local: LocalServer,
        root: str = "/mnt/tsfs/ckpt",
        block_bytes: int = 256 * 1024,
    ):
        self.local = local
        self.root = root.rstrip("/")
        self.block_bytes = block_bytes
        self._baseline: Dict[int, Dict[str, np.ndarray]] = {}
        self._last_step: Optional[int] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, state: PyTree, *, delta_from_last: bool = True) -> SaveInfo:
        t0 = time.perf_counter()
        baseline = None
        if delta_from_last and self._last_step is not None:
            baseline = self._baseline.get(self._last_step)
        stats: Dict[str, int] = {}

        def do_save(fs: FaaSFS) -> None:
            store = TensorStore(fs, prefix=self.root)
            s = store.save(
                f"step_{step}", state, baseline=baseline,
                block_bytes=self.block_bytes,
            )
            stats.update(s)
            # atomically flip the latest pointer (POSIX rename semantics)
            tmp = f"{self.root}/.latest.tmp"
            fd = fs.open(tmp, O_CREAT | O_TRUNC)
            fs.write(fd, json.dumps({"step": step}).encode())
            fs.close(fd)
            if fs.exists(f"{self.root}/latest"):
                fs.unlink(f"{self.root}/latest")
            fs.rename(tmp, f"{self.root}/latest")

        from repro.core.retry import InvocationStats

        inv = InvocationStats()
        run_function(self.local, do_save, stats=inv)
        flat = {n: np.asarray(a).copy() for n, a in flatten_with_names(state)}
        self._baseline = {step: flat}
        self._last_step = step
        return SaveInfo(
            step=step,
            commit_ts=inv.commit_ts,
            bytes_total=stats.get("bytes_total", 0),
            bytes_written=stats.get("bytes_written", 0),
            blocks_written=stats.get("blocks_written", 0),
            wall_s=time.perf_counter() - t0,
        )

    # ------------------------------------------------------------------ #
    def latest_step(self) -> Optional[int]:
        out: Dict[str, Optional[int]] = {"step": None}

        def do_read(fs: FaaSFS) -> None:
            if not fs.exists(f"{self.root}/latest"):
                return
            fd = fs.open(f"{self.root}/latest")
            n = fs.fstat(fd)["st_size"]
            out["step"] = json.loads(fs.pread(fd, n, 0))["step"]
            fs.close(fd)

        run_function(self.local, do_read, read_only=True)
        return out["step"]

    def restore(self, template: PyTree, step: Optional[int] = None) -> Tuple[PyTree, int]:
        """Snapshot-consistent restore; returns (state, step)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError("no checkpoint committed yet")
        holder: Dict[str, Any] = {}

        def do_load(fs: FaaSFS) -> None:
            store = TensorStore(fs, prefix=self.root)
            holder["flat"] = store.load(f"step_{step}")

        run_function(self.local, do_load, read_only=True)
        return unflatten_like(template, holder["flat"]), step
