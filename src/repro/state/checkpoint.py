"""Transactional, block-granular checkpointing on the FaaSFS core.

Checkpoints are FaaSFS state objects:

  * ``save`` runs as ONE transaction — a checkpoint is atomically visible or
    not at all (no torn checkpoints on worker failure; the paper's atomic
    commit applied to training state),
  * consecutive saves ship only dirty blocks: the ``block_delta`` kernel
    (or an exact numpy fallback) flags dirty blocks against the previous
    step's baseline, and ``TensorStore.save`` writes ONLY those blocks'
    exact new bytes — checkpoint cost scales with the update rate, not
    the parameter count,
  * ``restore`` pins a snapshot timestamp (multiversion read) so a restore
    is consistent even while training keeps committing, and loads through
    the zero-copy arena path (``TensorStore.load(zero_copy=True)``),
  * a ``latest`` pointer file is atomically renamed into place (POSIX rename
    atomicity, validated by the namespace OCC checks).

The manager runs on ``FunctionRuntime`` — implicit BEGIN/COMMIT, Conflict
restart, warm-container caches, read-only inference for restores — against
any ``BackendAPI`` (in-process, remote socket, sharded cluster).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.client import LocalServer
from repro.core.posix import FaaSFS, O_CREAT, O_TRUNC
from repro.core.runtime import FunctionRuntime, InvocationStats, runtime_for
from repro.core.tensorstate import TensorStore, flatten_with_names, unflatten_like

PyTree = Any


@dataclass
class SaveInfo:
    step: int
    commit_ts: int
    bytes_total: int
    bytes_written: int
    blocks_written: int
    wall_s: float


def dirty_block_indices(
    new: np.ndarray,
    old: np.ndarray,
    block_bytes: int,
    impl: str = "auto",
) -> Optional[List[int]]:
    """Block indices (of ``block_bytes`` granularity over the raw leaf
    bytes) where ``new`` differs from ``old``; ``None`` means "unknown,
    write conservatively" (shape/dtype changed, or no detector applies).

    ``impl="auto"`` picks the exact numpy byte-compare; pass a
    ``block_delta`` kernel impl (``"pallas"`` / ``"xla"`` /
    ``"pallas_interpret"``) to flag dirty blocks on-device via
    ``compute_block_delta``/``pack_dirty``. The kernel output is used
    ONLY as a dirty detector — the int8-quantized delta it also emits is
    lossy, so the blocks themselves are always written as exact new
    bytes by ``TensorStore.save``."""
    new = np.asarray(new)
    old = np.asarray(old)
    if new.dtype != old.dtype or new.shape != old.shape:
        return None
    nbytes = new.dtype.itemsize * int(new.size)
    if nbytes == 0:
        return []
    if impl != "auto" and new.dtype == np.float32 \
            and block_bytes % 4 == 0 and new.size >= block_bytes // 4:
        try:
            from repro.kernels.block_delta.ops import (
                blockify, compute_block_delta, pack_dirty,
            )
            block_elems = block_bytes // 4
            nb = blockify(np.ascontiguousarray(new).reshape(-1), block_elems)
            ob = blockify(np.ascontiguousarray(old).reshape(-1), block_elems)
            q, norm2, scale = compute_block_delta(nb, ob, impl=impl)
            idx, _, _ = pack_dirty(q, norm2, scale)
            return [int(i) for i in np.asarray(idx)]
        except Exception:
            pass  # no accelerator runtime: exact fallback below
    a = np.frombuffer(np.ascontiguousarray(new).tobytes(), dtype=np.uint8)
    b = np.frombuffer(np.ascontiguousarray(old).tobytes(), dtype=np.uint8)
    pad = (-len(a)) % block_bytes
    if pad:
        a = np.concatenate([a, np.zeros(pad, np.uint8)])
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    diff = np.any(
        a.reshape(-1, block_bytes) != b.reshape(-1, block_bytes), axis=1
    )
    return [int(i) for i in np.nonzero(diff)[0]]


class CheckpointManager:
    """Step-indexed checkpoints with kernel-flagged delta commits and
    snapshot restores, running on ``FunctionRuntime``.

    ``target`` is a ``FunctionRuntime`` or a bare ``LocalServer`` (a
    cached runtime is built over it). ``dirty_impl`` selects the dirty
    detector (``"auto"`` = exact numpy; ``"xla"``/``"pallas"`` = the
    block_delta kernel). ``max_staleness_s`` lets ``latest_step`` /
    ``restore`` be served from the lease tier's bounded-staleness view."""

    def __init__(
        self,
        target,
        root: str = "/mnt/tsfs/ckpt",
        block_bytes: int = 256 * 1024,
        dirty_impl: str = "auto",
        max_staleness_s: Optional[float] = None,
    ):
        if max_staleness_s is not None and not isinstance(
            target, FunctionRuntime
        ):
            self.runtime = runtime_for(target, max_staleness_s=max_staleness_s)
        else:
            self.runtime = runtime_for(target)
        self.local: LocalServer = self.runtime.local
        self.root = root.rstrip("/")
        self.block_bytes = block_bytes
        self.dirty_impl = dirty_impl
        self._baseline: Dict[int, Dict[str, np.ndarray]] = {}
        self._last_step: Optional[int] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, state: PyTree, *, delta_from_last: bool = True) -> SaveInfo:
        t0 = time.perf_counter()
        baseline = None
        if delta_from_last and self._last_step is not None:
            baseline = self._baseline.get(self._last_step)
        leaves = flatten_with_names(state)
        dirty: Optional[Dict[str, List[int]]] = None
        if baseline is not None:
            # dirty detection happens ONCE, outside the transaction:
            # a Conflict restart re-runs only the block writes
            dirty = {}
            for lname, arr in leaves:
                base = baseline.get(lname)
                if base is None:
                    continue
                idx = dirty_block_indices(
                    arr, base, self.block_bytes, self.dirty_impl
                )
                if idx is not None:
                    dirty[lname] = idx
        stats: Dict[str, int] = {}

        def do_save(fs: FaaSFS) -> None:
            stats.clear()
            store = TensorStore(fs, prefix=self.root)
            s = store.save(
                f"step_{step}", state, baseline=baseline,
                block_bytes=self.block_bytes, dirty_blocks=dirty,
            )
            stats.update(s)
            # atomically flip the latest pointer (POSIX rename semantics)
            tmp = f"{self.root}/.latest.tmp"
            fd = fs.open(tmp, O_CREAT | O_TRUNC)
            fs.write(fd, json.dumps({"step": step}).encode())
            fs.close(fd)
            if fs.exists(f"{self.root}/latest"):
                fs.unlink(f"{self.root}/latest")
            fs.rename(tmp, f"{self.root}/latest")

        inv = InvocationStats()
        self.runtime.invoke(do_save, stats=inv)
        flat = {n: np.asarray(a).copy() for n, a in leaves}
        self._baseline = {step: flat}
        self._last_step = step
        return SaveInfo(
            step=step,
            commit_ts=inv.commit_ts,
            bytes_total=stats.get("bytes_total", 0),
            bytes_written=stats.get("bytes_written", 0),
            blocks_written=stats.get("blocks_written", 0),
            wall_s=time.perf_counter() - t0,
        )

    # ------------------------------------------------------------------ #
    def latest_step(self) -> Optional[int]:
        out: Dict[str, Optional[int]] = {"step": None}

        def do_read(fs: FaaSFS) -> None:
            if not fs.exists(f"{self.root}/latest"):
                return
            fd = fs.open(f"{self.root}/latest")
            n = fs.fstat(fd)["st_size"]
            out["step"] = json.loads(fs.pread(fd, n, 0))["step"]
            fs.close(fd)

        self.runtime.invoke(do_read, read_only=True)
        return out["step"]

    def restore(
        self,
        template: PyTree,
        step: Optional[int] = None,
        *,
        zero_copy: bool = True,
    ) -> Tuple[PyTree, int]:
        """Snapshot-consistent restore; returns (state, step).

        With ``zero_copy=True`` (default) leaf arrays are READONLY views
        over arena buffers filled straight off the wire — ``.copy()``
        any leaf you need to mutate in place (functional updates, the
        normal jax style, need nothing)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError("no checkpoint committed yet")
        holder: Dict[str, Any] = {}

        def do_load(fs: FaaSFS) -> None:
            store = TensorStore(fs, prefix=self.root)
            holder["flat"] = store.load(f"step_{step}", zero_copy=zero_copy)

        self.runtime.invoke(do_load, read_only=True)
        return unflatten_like(template, holder["flat"]), step
