"""AdamW with decoupled weight decay and global-norm clipping.

Hand-rolled (no optax dependency); optimizer state is a pytree mirroring the
params, so it shards with the same PartitionSpecs (ZeRO-style: the FSDP axis
shards first/second moments along with the master weights).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to lr_min."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: PyTree) -> Dict[str, PyTree]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_p: PyTree) -> Dict[str, PyTree]:
    sds = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_p)
    return {
        "m": sds,
        "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_p),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    cfg: AdamWConfig,
    params: PyTree,
    grads: PyTree,
    opt_state: Dict[str, PyTree],
) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jax.Array]]:
    count = opt_state["count"] + 1
    lr = lr_schedule(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step + decay)
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
