"""Paged KV-cache manager over the FaaSFS block model.

KV pages are the serving-side twin of the paper's file blocks: fixed-size
(page_tokens) slabs of per-layer K/V state, owned by a free-list allocator,
referenced by per-sequence page tables, and — the FaaSFS twist —
*persistable*: a finished/evicted sequence's pages can be committed to the
block store and re-attached later (prefix reuse across requests, exactly
the cross-invocation cache survival the paper builds on). Committed pages
are read back with snapshot consistency, so a server can re-hydrate a
conversation's KV state while other workers keep committing.

The dense-assembly path (``materialize``) produces the (L, B, S, KV, hd)
layout the jit'd ``decode_step`` consumes; on TPU a paged decode-attention
kernel would read the page table directly (recorded future work).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.posix import FaaSFS, O_CREAT
from repro.core.runtime import InvocationStats, runtime_for


@dataclass
class _Sequence:
    pages: List[int] = field(default_factory=list)
    length: int = 0


class PagedKVCache:
    """Fixed-pool paged allocator for decode KV state (host-side)."""

    def __init__(self, cfg: ModelConfig, *, num_pages: int, page_tokens: int = 16,
                 dtype=np.float32):
        if not cfg.has_attention:
            raise ValueError("paged KV cache requires an attention arch")
        self.cfg = cfg
        self.page_tokens = page_tokens
        self.num_pages = num_pages
        shape = (num_pages, cfg.num_layers, page_tokens, cfg.num_kv_heads, cfg.head_dim)
        self.k_pages = np.zeros(shape, dtype)
        self.v_pages = np.zeros(shape, dtype)
        self._free = list(range(num_pages - 1, -1, -1))
        self._seqs: Dict[str, _Sequence] = {}

    # ------------------------------------------------------------------ #
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def new_sequence(self, seq_id: str) -> None:
        if seq_id in self._seqs:
            raise KeyError(f"sequence {seq_id} exists")
        self._seqs[seq_id] = _Sequence()

    def length(self, seq_id: str) -> int:
        return self._seqs[seq_id].length

    def _page_for(self, seq: _Sequence, pos: int) -> Tuple[int, int]:
        pi, off = divmod(pos, self.page_tokens)
        while len(seq.pages) <= pi:
            if not self._free:
                raise MemoryError("KV page pool exhausted")
            seq.pages.append(self._free.pop())
        return seq.pages[pi], off

    def append(self, seq_id: str, k: np.ndarray, v: np.ndarray) -> int:
        """Append one token's K/V. k/v: (L, KV, hd). Returns new length."""
        seq = self._seqs[seq_id]
        page, off = self._page_for(seq, seq.length)
        self.k_pages[page, :, off] = k
        self.v_pages[page, :, off] = v
        seq.length += 1
        return seq.length

    def materialize(self, seq_id: str, max_seq: int) -> Tuple[np.ndarray, np.ndarray]:
        """Assemble the dense (L, max_seq, KV, hd) views for decode_step."""
        cfg, seq = self.cfg, self._seqs[seq_id]
        out_shape = (cfg.num_layers, max_seq, cfg.num_kv_heads, cfg.head_dim)
        k = np.zeros(out_shape, self.k_pages.dtype)
        v = np.zeros(out_shape, self.v_pages.dtype)
        for pi, page in enumerate(seq.pages):
            lo = pi * self.page_tokens
            hi = min(lo + self.page_tokens, seq.length, max_seq)
            if hi <= lo:
                break
            k[:, lo:hi] = self.k_pages[page][:, : hi - lo]
            v[:, lo:hi] = self.v_pages[page][:, : hi - lo]
        return k, v

    def release(self, seq_id: str) -> None:
        seq = self._seqs.pop(seq_id)
        self._free.extend(reversed(seq.pages))

    # ------------------------------------------------------------------ #
    # FaaSFS persistence: commit / re-attach sequences across invocations
    # ------------------------------------------------------------------ #
    # ``target`` below is a FunctionRuntime or a bare LocalServer (a
    # cached runtime is built over it) — persistence runs as real FaaS
    # invocations: implicit BEGIN/COMMIT, Conflict restart, and the
    # read-only fast path for attach, over any BackendAPI transport.
    # Layout: ``{prefix}/{seq_id}.len`` (8-byte LE length) plus one
    # ``.p{i}k`` / ``.p{i}v`` file per page — K and V separated so each
    # file maps onto ONE contiguous pool destination and ``attach`` can
    # land page bytes straight off the wire into the pool (zero-copy,
    # counted by ``Transaction.bytes_sunk``).
    def persist(self, target, seq_id: str, *, prefix: str = "/mnt/tsfs/kv") -> int:
        """Commit a sequence's pages atomically; returns commit timestamp."""
        seq = self._seqs[seq_id]
        pages_k = [self.k_pages[p] for p in seq.pages]
        pages_v = [self.v_pages[p] for p in seq.pages]
        inv = InvocationStats()

        def do(fs: FaaSFS) -> None:
            meta = f"{prefix}/{seq_id}.len"
            fd = fs.open(meta, O_CREAT)
            fs.pwrite(fd, int(seq.length).to_bytes(8, "little"), 0)
            fs.close(fd)
            for i, (pk, pv) in enumerate(zip(pages_k, pages_v)):
                fd = fs.open(f"{prefix}/{seq_id}.p{i}k", O_CREAT)
                fs.pwrite(fd, pk.tobytes(), 0)
                fs.close(fd)
                fd = fs.open(f"{prefix}/{seq_id}.p{i}v", O_CREAT)
                fs.pwrite(fd, pv.tobytes(), 0)
                fs.close(fd)

        runtime_for(target).invoke(do, stats=inv)
        return inv.commit_ts

    def attach(self, target, seq_id: str, *, prefix: str = "/mnt/tsfs/kv") -> int:
        """Re-hydrate a persisted sequence (snapshot-consistent read).

        Page bytes are read INTO the pool slabs (``pread_into``): the
        destination of every full block is the ``k_pages``/``v_pages``
        memory itself, so a remote attach performs zero per-block
        payload copies beyond the single wire decode."""
        self.new_sequence(seq_id)
        seq = self._seqs[seq_id]
        page_shape = self.k_pages.shape[1:]
        page_bytes = int(np.prod(page_shape)) * self.k_pages.dtype.itemsize
        holder: Dict[str, int] = {}

        def do(fs: FaaSFS) -> None:
            fd = fs.open(f"{prefix}/{seq_id}.len")
            length = int.from_bytes(fs.pread(fd, 8, 0), "little")
            fs.close(fd)
            holder["length"] = length
            n_pages = -(-length // self.page_tokens)
            for i in range(n_pages):
                # idempotent across Conflict/staleness restarts:
                # _page_for only appends pages the sequence lacks, and a
                # re-run simply overwrites the same pool slabs
                page, _ = self._page_for(seq, i * self.page_tokens)
                for suffix, pool in (("k", self.k_pages), ("v", self.v_pages)):
                    fd = fs.open(f"{prefix}/{seq_id}.p{i}{suffix}")
                    n = fs.fstat(fd)["st_size"]
                    if n != page_bytes:
                        raise ValueError(
                            f"kv page {seq_id}.p{i}{suffix}: {n} bytes, "
                            f"expected {page_bytes}"
                        )
                    fs.pread_into(fd, n, 0, memoryview(pool[page]).cast("B"))
                    fs.close(fd)

        runtime_for(target).invoke(do, read_only=True)
        seq.length = int(holder["length"])
        return seq.length
