"""Snapshot serving: inference replicas on multiversioned parameter state.

A serving replica pins a committed version (the paper's snapshot reads):
requests are served from a consistent parameter snapshot even while training
transactions keep committing. ``refresh()`` advances to the newest committed
version, pulling only changed blocks (fine-grained cache updates) — the
serving-side analogue of delta checkpoint restore.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.client import LocalServer
from repro.core.posix import FaaSFS
from repro.core.runtime import runtime_for
from repro.core.tensorstate import TensorStore, unflatten_like

PyTree = Any


@dataclass
class ServeStats:
    requests: int = 0
    tokens: int = 0
    refreshes: int = 0
    refresh_bytes: int = 0
    wall_s: float = 0.0


class SnapshotServer:
    """Batched decode against a pinned parameter snapshot."""

    def __init__(
        self,
        local: LocalServer,
        decode_fn: Callable[[PyTree, Any], Any],
        template: PyTree,
        *,
        root: str = "/mnt/tsfs/train",
        name: str = "state",
    ):
        self.local = local
        self.decode_fn = decode_fn
        self.template = template
        self.root = root.rstrip("/")
        self.name = name
        self.params: Optional[PyTree] = None
        self.version: int = -1
        self.stats = ServeStats()

    # ------------------------------------------------------------------ #
    def refresh(self) -> int:
        """Load (or delta-update to) the latest committed snapshot."""
        holder: Dict[str, Any] = {}
        before = self.local.misses

        def do_read(fs: FaaSFS) -> None:
            store = TensorStore(fs, prefix=self.root)
            holder["flat"] = store.load(self.name, zero_copy=True)
            holder["ts"] = fs.txn.read_ts

        runtime_for(self.local).invoke(do_read, read_only=True)
        self.params = unflatten_like(self.template, holder["flat"])
        self.version = holder["ts"]
        self.stats.refreshes += 1
        self.stats.refresh_bytes += (
            (self.local.misses - before) * self.local.backend.block_size
        )
        return self.version

    # ------------------------------------------------------------------ #
    def serve(self, batch: Any) -> Any:
        if self.params is None:
            self.refresh()
        t0 = time.perf_counter()
        out = self.decode_fn(self.params, batch)
        self.stats.requests += 1
        self.stats.wall_s += time.perf_counter() - t0
        return out
