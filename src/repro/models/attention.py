"""GQA attention: full/causal/sliding-window, chunked long-seq path, decode.

The ``xla`` implementation here is the pure-jnp reference used for CPU tests
and the dry-run; on TPU the flash-attention Pallas kernel
(`repro.kernels.flash_attention`) replaces the core softmax(QK^T)V when
``impl="pallas"``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, KV, G, hd); k: (B, Sk, KV, hd) -> (B, KV, G, Sq, Sk)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k)


def _mask_bias(
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    causal: bool,
    window: int,
    kv_len: Optional[jax.Array],
) -> jax.Array:
    """Additive mask bias (Sq, Sk) in float32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_len is not None:
        ok &= k_pos[None, :] < kv_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bias: jax.Array,
    scale: float,
) -> jax.Array:
    """q: (B,Sq,KV,G,hd), k/v: (B,Sk,KV,hd), bias: (Sq,Sk) -> (B,Sq,KV,G,hd)."""
    scores = _gqa_scores(q, k).astype(jnp.float32) * scale
    scores = scores + bias[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def attention_xla(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,
    q_chunk: int = 0,
) -> jax.Array:
    """GQA attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd). Returns (B, Sq, H, hd).
    ``q_chunk > 0`` scans over query chunks so Sq x Sk scores never
    materialize (the long-sequence / prefill path; also the oracle the
    flash kernel is validated against).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = 1.0 / (hd ** 0.5)
    Sk = k.shape[1]
    k_pos = jnp.arange(Sk)

    if q_chunk and Sq > q_chunk and Sq % q_chunk == 0:
        n = Sq // q_chunk
        qs = qg.reshape(B, n, q_chunk, KV, G, hd).swapaxes(0, 1)

        @jax.checkpoint
        def chunk(qc, i):
            q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            bias = _mask_bias(q_pos, k_pos, causal=causal, window=window, kv_len=kv_len)
            return _sdpa(qc, k, v, bias, scale)

        def body(_, inp):
            qc, i = inp
            return (), chunk(qc, i)

        _, out = jax.lax.scan(body, (), (qs, jnp.arange(n)))
        out = out.swapaxes(0, 1).reshape(B, Sq, KV, G, hd)
    else:
        q_pos = q_offset + jnp.arange(Sq)
        bias = _mask_bias(q_pos, k_pos, causal=causal, window=window, kv_len=kv_len)
        out = _sdpa(qg, k, v, bias, scale)
    return out.reshape(B, Sq, H, hd)


def decode_attention_xla(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_index: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token decode. q: (B, 1, H, hd); caches: (B, S, KV, hd).

    ``cur_index`` is the position of the query token; cache entries at
    positions <= cur_index are attended (the new token's k/v must already be
    written). Sliding window limits attention to the last ``window`` keys.
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    scale = 1.0 / (hd ** 0.5)
    S = k_cache.shape[1]
    k_pos = jnp.arange(S)
    ok = k_pos <= cur_index
    if window > 0:
        ok &= k_pos > (cur_index - window)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)  # (S,)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32) * scale
    scores = scores + bias[None, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(B, 1, H, hd)
