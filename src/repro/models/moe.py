"""Token-choice MoE with group-local, capacity-bounded dispatch.

Dispatch is performed PER DATA-PARALLEL GROUP (``groups`` = number of batch
shards): each group routes its own tokens, computes position-in-expert with
a group-local cumulative sum (no cross-shard prefix sums), and scatters into
a per-group (E, C, D) buffer. The buffer is replicated across the ``model``
axis at dispatch (tokens are batch-sharded there), then *sliced* to the
local expert shard for the expert FFNs — a free reshard — so the heavy
matmuls are expert-parallel over ``model``. The combine gathers expert
outputs back (one all-gather of the (E_local -> E) outputs per layer), which
the §Perf pass attacks with a shard_map all-to-all.

Capacity semantics follow GShard/Switch: C = ceil(cf * T_g * k / E); tokens
beyond capacity are dropped (their combine weight is zero).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import constrain


def top_k_routing(router_logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """router_logits: (..., E) -> (weights (...,k) fp32 normalized, ids (...,k))."""
    gates = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(gates, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, ids


def load_balance_loss(logits: jax.Array, ids: jax.Array, num_experts: int) -> jax.Array:
    """Switch-style auxiliary loss over all tokens."""
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(gates.reshape(-1, num_experts), axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, num_experts, dtype=jnp.float32), axis=-2)
        .reshape(-1, num_experts),
        axis=0,
    )
    return num_experts * jnp.sum(me * ce)


def moe_ffn(
    x: jax.Array,
    router: jax.Array,
    wi: jax.Array,
    wg: jax.Array,
    wo: jax.Array,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    groups: int = 1,
    token_spec: Optional[P] = None,
    buf_spec: Optional[P] = None,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (B, S, D), aux_loss.

    router: (D, E); wi/wg: (E, D, F); wo: (E, F, D).
    """
    B, S, D = x.shape
    E, k = num_experts, top_k
    G = groups if B % max(groups, 1) == 0 else 1
    Tg = (B // G) * S
    xg = x.reshape(G, Tg, D)
    if token_spec is not None:
        xg = constrain(xg, token_spec)

    logits = jnp.einsum("gtd,de->gte", xg, router)          # (G, Tg, E)
    weights, ids = top_k_routing(logits, k)                  # (G, Tg, k)
    aux = load_balance_loss(logits, ids, E)

    capacity = max(k, int(capacity_factor * Tg * k / E))

    flat_ids = ids.reshape(G, Tg * k)
    flat_w = weights.reshape(G, Tg * k)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)    # (G, Tg*k, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot            # group-local
    pos = jnp.take_along_axis(pos_all, flat_ids[..., None], axis=2)[..., 0]
    keep = pos < capacity
    pos = jnp.where(keep, pos, capacity - 1)

    tok = jnp.arange(Tg * k) // k                            # slot -> token
    xs = jnp.take(xg, tok, axis=1) * keep[..., None].astype(x.dtype)

    # G is a true batch dim of the scatter (vmap -> operand_batching_dims),
    # so SPMD keeps the dispatch local to each data shard instead of
    # replicating the (G, E, C, D) buffer.
    def scatter_group(ids_g, pos_g, xs_g):
        return jnp.zeros((E, capacity, D), dtype=x.dtype).at[ids_g, pos_g].add(
            xs_g, mode="drop"
        )

    buf = jax.vmap(scatter_group)(flat_ids, pos, xs)
    if buf_spec is not None:
        # free reshard: buf is model-replicated after dispatch; slicing the
        # expert dim onto the model axis localizes the FFN compute
        buf = constrain(buf, buf_spec)

    h = jnp.einsum("gecd,edf->gecf", buf, wi)
    g = jnp.einsum("gecd,edf->gecf", buf, wg)
    h = h * g * jax.nn.sigmoid(g.astype(jnp.float32)).astype(h.dtype)  # h*silu(g)
    ye = jnp.einsum("gecf,efd->gecd", h, wo)
    if buf_spec is not None:
        ye = constrain(ye, buf_spec)

    def gather_group(ye_g, ids_g, pos_g):
        return ye_g[ids_g, pos_g]

    ys = jax.vmap(gather_group)(ye, flat_ids, pos)
    ys = ys * (flat_w * keep)[..., None].astype(ye.dtype)
    out = ys.reshape(G, Tg, k, D).sum(axis=2)       # combine the k slots
    return out.reshape(B, S, D), aux


# --------------------------------------------------------------------------- #
# §Perf: expert-parallel MoE via shard_map all-to-all
# --------------------------------------------------------------------------- #
def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across JAX generations: new JAX exposes it at the top
    level (with ``check_vma``); older releases only have
    ``jax.experimental.shard_map`` (with ``check_rep``). Semantics are
    identical for our use — both checks are disabled because the combine
    emits an unreplicated scalar aux loss."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as legacy_shard_map

    return legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def moe_ffn_a2a(
    x: jax.Array,
    router: jax.Array,
    wi: jax.Array,
    wg: jax.Array,
    wo: jax.Array,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    mesh,
    batch_axes: Tuple[str, ...],
    model_axis: str = "model",
    seq_axis: Optional[str] = "model",
) -> Tuple[jax.Array, jax.Array]:
    """Dropped-token-bounded MoE with explicit expert-parallel all-to-all.

    Replaces the global-view dispatch (whose combine XLA lowers as a psum of
    the k-expanded token tensor — measured 1.27 TB/step of all-reduce on
    qwen3-30B) with the canonical EP exchange:

      route locally -> scatter into per-expert send slabs -> all_to_all over
      the model axis -> local expert FFNs -> reverse all_to_all -> local
      weighted combine.

    Wire cost: 2 * T_local * k * cf * D bytes per device per layer — no
    all-reduce, no model-replicated buffers. Tokens stay sequence-sharded.
    """
    import numpy as np
    from jax.sharding import PartitionSpec

    B, S, D = x.shape
    E, k = num_experts, top_k
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes[model_axis]
    n_batch = 1
    for a in batch_axes or ():
        n_batch *= sizes[a]
    assert E % n_model == 0, (E, n_model)
    e_loc = E // n_model
    t_loc = (B // n_batch) * (S // (n_model if seq_axis else 1))
    cap = max(1, int(capacity_factor * t_loc * k / E))

    def local(x_l, router_l, wi_l, wg_l, wo_l):
        # x_l: (B_loc, S_loc, D); wi_l: (e_loc, D, F)
        b_l, s_l, _ = x_l.shape
        t = b_l * s_l
        xf = x_l.reshape(t, D)
        logits = jnp.einsum("td,de->te", xf, router_l)
        weights, ids = top_k_routing(logits, k)
        # load-balance loss: pmean the me/ce VECTORS before their product so
        # the result equals the global-batch loss exactly
        gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        me = jnp.mean(gates, axis=0)
        ce = jnp.mean(
            jnp.sum(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=1), axis=0
        )
        axes = (model_axis,) + tuple(batch_axes or ())
        for a in axes:
            me = jax.lax.pmean(me, a)
            ce = jax.lax.pmean(ce, a)
        aux = E * jnp.sum(me * ce)

        flat_ids = ids.reshape(t * k)
        flat_w = weights.reshape(t * k)
        onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - onehot, flat_ids[:, None], axis=1
        )[:, 0]
        keep = pos < cap
        pos_c = jnp.where(keep, pos, cap - 1)
        tok = jnp.arange(t * k) // k
        xs = jnp.take(xf, tok, axis=0) * keep[:, None].astype(xf.dtype)

        send = jnp.zeros((E, cap, D), xf.dtype).at[flat_ids, pos_c].add(
            xs, mode="drop"
        )
        # exchange: each peer gets its expert slab; we receive every peer's
        # slab for OUR experts
        recv = jax.lax.all_to_all(
            send, model_axis, split_axis=0, concat_axis=1, tiled=True
        )  # (e_loc, n_model*cap, D)

        h = jnp.einsum("ecd,edf->ecf", recv, wi_l)
        g = jnp.einsum("ecd,edf->ecf", recv, wg_l)
        h = h * g * jax.nn.sigmoid(g.astype(jnp.float32)).astype(h.dtype)
        ye = jnp.einsum("ecf,efd->ecd", h, wo_l)

        back = jax.lax.all_to_all(
            ye, model_axis, split_axis=1, concat_axis=0, tiled=True
        )  # (E, cap, D): our tokens, processed
        ys = back[flat_ids, pos_c] * (flat_w * keep)[:, None].astype(back.dtype)
        out = ys.reshape(t, k, D).sum(axis=1)
        return out.reshape(b_l, s_l, D), aux

    bspec = tuple(batch_axes) if batch_axes else None
    x_spec = PartitionSpec(bspec, seq_axis, None)
    w_spec = PartitionSpec(model_axis, None, None)
    out, aux = _shard_map(
        local,
        mesh=mesh,
        in_specs=(x_spec, PartitionSpec(None, None), w_spec, w_spec,
                  PartitionSpec(model_axis, None, None)),
        out_specs=(x_spec, PartitionSpec()),
    )(x, router, wi, wg, wo)
    return out, aux
