"""Step builders + sharding rules: the bridge between model code and pjit.

Everything the dry-run, trainer and server need for one (arch x shape x mesh)
cell: abstract input/state trees with NamedShardings attached, and the jit'd
``train_step`` / ``prefill_step`` / ``decode_step`` with in/out shardings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import model as M
from repro.models.layers import batch_axes_for
from repro.optim import adamw

PyTree = Any


# --------------------------------------------------------------------------- #
# Sharding rules (logical axis -> mesh axis)
# --------------------------------------------------------------------------- #
def train_rules(fsdp_axis: Any = "data", tensor_axis: str = "model") -> Dict:
    """FSDP on the data axis + tensor/expert parallel on the model axis."""
    return {
        "vocab": tensor_axis,
        "residual": fsdp_axis,
        "heads": tensor_axis,
        "kv": tensor_axis,
        "ffn": tensor_axis,
        "experts": tensor_axis,
        "expert_ffn": tensor_axis,  # fallback when E doesn't divide (mixtral)
        "dinner": tensor_axis,
        "layers": None,
        None: None,
    }


def train_rules_v2() -> Dict:
    """§Perf iteration: FSDP over OUTPUT dims only.

    Baseline v1 shards the weights' d_model (contraction) dim over ``data``,
    which XLA sometimes lowers as partial-matmul + output all-reduce instead
    of a weight all-gather (measured: 493 GB/step of projection all-reduce
    on deepseek-33b). v2 keeps contraction dims unsharded and spreads the
    output dims over ("data","model"), so the only way to compute is to
    all-gather the (much smaller) weight shard — and weight grads
    reduce-scatter naturally (ZeRO). Per-device weight memory is identical.
    """
    return {
        "vocab": ("data", "model"),
        "residual": None,
        "heads": ("data", "model"),
        "kv": ("data", "model"),
        "ffn": ("data", "model"),
        "experts": "model",
        "expert_ffn": "data",
        "dinner": ("data", "model"),
        "layers": None,
        None: None,
    }


def decode_rules(fsdp_axis: Any = "data", tensor_axis: str = "model") -> Dict:
    """Inference keeps the same 2-D weight layout (baseline; see §Perf)."""
    return train_rules(fsdp_axis, tensor_axis)


@dataclass(frozen=True)
class CellPlan:
    """Resolved plan for one (arch x shape x mesh) cell."""

    cfg: ModelConfig
    shape: ShapeCell
    batch_axes: Optional[Tuple[str, ...]]
    rules: Dict
    act: M.ActSharding
    q_chunk: int
    ce_chunk: int
    remat_policy: object = None
    kv_dtype: object = None   # jnp.int8 => quantized KV cache (§Perf)


def plan_cell(
    cfg: ModelConfig,
    shape: ShapeCell,
    mesh: Mesh,
    *,
    overrides: Optional[Dict] = None,
) -> CellPlan:
    """Baseline sharding plan for a cell; ``overrides`` feed §Perf hillclimbs."""
    overrides = overrides or {}
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = batch_axes_for(shape.global_batch, sizes)
    seq_axis = overrides.get("seq_axis", "model")
    rules = overrides.get("rules") or (
        train_rules() if shape.kind == "train" else decode_rules()
    )
    groups = 1
    if baxes is not None:
        groups = 1
        for a in baxes:
            groups *= sizes[a]
    moe_a2a = None
    if overrides.get("moe_impl") == "a2a" and cfg.num_experts % sizes.get("model", 1) == 0:
        moe_a2a = dict(
            mesh=mesh, batch_axes=baxes, model_axis="model",
            seq_axis=seq_axis if shape.kind in ("train", "prefill") else None,
        )
    if shape.kind == "train" or shape.kind == "prefill":
        act = M.ActSharding(
            residual=P(baxes, seq_axis, None),
            logits=P(baxes, None, "model"),
            moe_tokens=P(baxes, None, None),
            moe_buf=P(baxes, "model", None, None),
            moe_groups=groups,
            moe_a2a=moe_a2a,
            kv_cache=P(None, baxes, seq_axis, None, None),
        )
    else:  # decode
        act = M.ActSharding(
            decode_residual=P(baxes, None, None),
            moe_tokens=P(baxes, None, None),
            moe_buf=P(baxes, "model", None, None),
            moe_groups=groups,
            kv_cache=P(None, baxes, "model", None, None),
        )
    act = overrides.get("act", act)
    remat_policy = overrides.get("remat_policy")
    default_qc = 1024 if shape.seq_len >= 4096 else 0
    if shape.kind == "train" and cfg.d_model >= 7168:
        default_qc = 512  # bound f32 score transients for the widest models
    q_chunk = overrides.get("q_chunk", default_qc)
    ce_chunk = overrides.get("ce_chunk", 512)
    kv_dtype = overrides.get("kv_dtype")
    return CellPlan(cfg, shape, baxes, rules, act, q_chunk, ce_chunk,
                    remat_policy, kv_dtype)


# --------------------------------------------------------------------------- #
# Abstract inputs (ShapeDtypeStructs with shardings — no allocation)
# --------------------------------------------------------------------------- #
def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def input_specs(plan: CellPlan, mesh: Mesh) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg, shape = plan.cfg, plan.shape
    B = shape.global_batch
    bspec = P(plan.batch_axes)
    if shape.kind in ("train", "prefill"):
        S = shape.seq_len
        text = S - cfg.vision_prefix if cfg.vision_prefix else S
        specs = {
            "tokens": _sds((B, text), jnp.int32, mesh, P(plan.batch_axes, None)),
        }
        if cfg.vision_prefix:
            specs["pixel_embeds"] = _sds(
                (B, cfg.vision_prefix, cfg.d_model), jnp.bfloat16,
                mesh, P(plan.batch_axes, None, None),
            )
        if shape.kind == "train":
            specs["labels"] = _sds((B, text), jnp.int32, mesh, P(plan.batch_axes, None))
            specs["mask"] = _sds((B, text), jnp.float32, mesh, P(plan.batch_axes, None))
        return specs
    # decode: one new token against a seq_len KV cache
    return {
        "tokens": _sds((B, 1), jnp.int32, mesh, P(plan.batch_axes, None)),
        "cur_index": jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
    }


def abstract_sharded_params(plan: CellPlan, mesh: Mesh, dtype=jnp.float32) -> PyTree:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    specs = M.param_partition_specs(plan.cfg, plan.rules, axis_sizes)
    absp = M.abstract_params(plan.cfg, dtype)
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        ),
        absp,
        specs,
    )


def abstract_train_state(plan: CellPlan, mesh: Mesh) -> Dict[str, PyTree]:
    params = abstract_sharded_params(plan, mesh, jnp.float32)
    opt = adamw.abstract_opt_state(params)
    # moments shard exactly like params (ZeRO)
    opt = {
        "m": jax.tree.map(
            lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=p.sharding),
            opt["m"], params),
        "v": jax.tree.map(
            lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=p.sharding),
            opt["v"], params),
        "count": jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
    }
    return {"params": params, "opt": opt}


def abstract_sharded_cache(plan: CellPlan, mesh: Mesh) -> PyTree:
    cfg, shape = plan.cfg, plan.shape
    kv_dtype = plan.kv_dtype or jnp.bfloat16
    cache = M.abstract_decode_cache(cfg, shape.global_batch, shape.seq_len, kv_dtype)
    specs = cache_partition_specs(plan)
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        ),
        cache,
        specs,
    )


def cache_partition_specs(plan: CellPlan) -> PyTree:
    cfg = plan.cfg
    specs: Dict[str, P] = {}
    if cfg.has_attention:
        kv = P(None, plan.batch_axes, "model", None, None)  # seq over model
        specs["k"] = kv
        specs["v"] = kv
        if plan.kv_dtype is not None and plan.kv_dtype != jnp.bfloat16:
            sc = P(None, plan.batch_axes, "model", None)
            specs["k_scale"] = sc
            specs["v_scale"] = sc
    if cfg.has_ssm:
        specs["conv"] = P(None, plan.batch_axes, None, "model")
        specs["ssm"] = P(None, plan.batch_axes, "model", None)
    return specs


# --------------------------------------------------------------------------- #
# Step builders
# --------------------------------------------------------------------------- #
def make_train_step(plan: CellPlan, opt_cfg: adamw.AdamWConfig):
    cfg = plan.cfg

    def train_step(state: Dict[str, PyTree], batch: Dict[str, jax.Array]):
        def lf(params):
            return M.loss_fn(
                cfg, params, batch,
                shardings=plan.act,
                q_chunk=plan.q_chunk,
                ce_chunk=plan.ce_chunk,
                remat_policy=plan.remat_policy,
            )

        (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        new_params, new_opt, opt_metrics = adamw.adamw_update(
            opt_cfg, state["params"], grads, state["opt"]
        )
        metrics = dict(metrics, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(plan: CellPlan):
    cfg = plan.cfg

    def prefill_step(params: PyTree, batch: Dict[str, jax.Array]):
        return M.prefill(
            cfg, params, batch["tokens"],
            pixel_embeds=batch.get("pixel_embeds"),
            shardings=plan.act,
            q_chunk=plan.q_chunk or 1024,
        )

    return prefill_step


def make_decode_step(plan: CellPlan):
    cfg = plan.cfg

    def dstep(params: PyTree, cache: PyTree, batch: Dict[str, jax.Array]):
        return M.decode_step(
            cfg, params, cache, batch["tokens"], batch["cur_index"],
            shardings=plan.act,
        )

    return dstep


def lower_cell(
    plan: CellPlan,
    mesh: Mesh,
    *,
    opt_cfg: Optional[adamw.AdamWConfig] = None,
    donate: bool = True,
):
    """Lower the cell's step over ``mesh``. Returns jax ``Lowered``."""
    cfg, shape = plan.cfg, plan.shape
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            state = abstract_train_state(plan, mesh)
            batch = input_specs(plan, mesh)
            fn = make_train_step(plan, opt_cfg)
            jf = jax.jit(fn, donate_argnums=(0,) if donate else ())
            return jf.lower(state, batch)
        if shape.kind == "prefill":
            params = abstract_sharded_params(plan, mesh, jnp.bfloat16)
            batch = input_specs(plan, mesh)
            fn = make_prefill_step(plan)
            return jax.jit(fn).lower(params, batch)
        # decode
        params = abstract_sharded_params(plan, mesh, jnp.bfloat16)
        cache = abstract_sharded_cache(plan, mesh)
        batch = input_specs(plan, mesh)
        fn = make_decode_step(plan)
        jf = jax.jit(fn, donate_argnums=(1,) if donate else ())
        return jf.lower(params, cache, batch)
