"""Shared building blocks: norms, RoPE, sharding helpers, embeddings, MLP."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------- #
# Sharding helpers
# --------------------------------------------------------------------------- #
def mesh_active() -> bool:
    """True when running under a named mesh (pjit); False on bare CPU."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - old jax fallback
        return False
    return bool(m.shape_tuple)


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """``with_sharding_constraint`` that is a no-op outside a mesh context."""
    if not mesh_active():
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def batch_axes_for(global_batch: int, mesh_axis_sizes: dict) -> Optional[tuple]:
    """Largest prefix of ("pod","data") that evenly divides the batch.

    ``long_500k`` has batch 1 — replicate instead of forcing uneven sharding.
    """
    axes = [a for a in ("pod", "data") if a in mesh_axis_sizes]
    chosen = []
    prod = 1
    for a in axes:
        if global_batch % (prod * mesh_axis_sizes[a]) == 0:
            chosen.append(a)
            prod *= mesh_axis_sizes[a]
    if not chosen:
        return None
    return tuple(chosen)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def rms_norm_headwise(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """QK-norm: normalize over the trailing head_dim. scale: (head_dim,)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim//2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd//2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd//2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, hd//2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Embedding / head
# --------------------------------------------------------------------------- #
def embed_tokens(embedding: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(embedding, tokens, axis=0).astype(compute_dtype)


# --------------------------------------------------------------------------- #
# Dense SwiGLU MLP
# --------------------------------------------------------------------------- #
def swiglu_mlp(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    """x: (B, S, D); wi/wg: (D, F); wo: (F, D)."""
    h = jnp.einsum("bsd,df->bsf", x, wi)
    g = jnp.einsum("bsd,df->bsf", x, wg)
    h = h * jax.nn.sigmoid(g.astype(jnp.float32)).astype(h.dtype) * g  # silu(g)*h
    return jnp.einsum("bsf,fd->bsd", h, wo)


def cross_entropy_chunked(
    x: jax.Array,
    head: jax.Array,
    labels: jax.Array,
    mask: jax.Array,
    *,
    chunk: int = 512,
    logits_spec: Optional[P] = None,
) -> jax.Array:
    """Memory-bounded CE: scan over sequence chunks, remat the chunk body.

    x: (B, S, D) final hidden states; head: (D, V); labels/mask: (B, S).
    Returns (sum_nll, sum_mask).
    """
    B, S, D = x.shape
    n_chunks = max(1, S // chunk)
    c = S // n_chunks
    xs = x[:, : n_chunks * c].reshape(B, n_chunks, c, D).swapaxes(0, 1)
    ls = labels[:, : n_chunks * c].reshape(B, n_chunks, c).swapaxes(0, 1)
    ms = mask[:, : n_chunks * c].reshape(B, n_chunks, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(xc, lc, mc):
        logits = jnp.einsum("bcd,dv->bcv", xc, head)
        if logits_spec is not None:
            logits = constrain(logits, logits_spec)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return jnp.sum(nll)

    def body(carry, inputs):
        xc, lc, mc = inputs
        return carry + chunk_loss(xc, lc, mc), ()

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls, ms))
    return total, jnp.sum(mask.astype(jnp.float32))
