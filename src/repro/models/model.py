"""Model construction: param defs, init, abstract shapes, sharding, forward.

A single table of ``ParamDef``s per architecture drives three things:
  * real initialization (smoke tests, the 100M training example),
  * abstract ``ShapeDtypeStruct`` trees (the multi-pod dry-run),
  * logical-axis -> mesh-axis sharding specs (pjit in/out shardings).

The decoder stack is a ``lax.scan`` over layer-stacked parameters with
rematerialization, so compile time and HLO size stay bounded for 80-layer
configs while the roofline analyzer scales while-body costs by trip count.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import attention_xla, decode_attention_xla
from repro.models.layers import (
    constrain,
    cross_entropy_chunked,
    embed_tokens,
    rms_norm,
    rms_norm_headwise,
    swiglu_mlp,
)
from repro.models.moe import moe_ffn

PyTree = Any


# --------------------------------------------------------------------------- #
# Param defs
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ParamDef:
    path: Tuple[str, ...]
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | out_normal | zeros | ones | ssm_A | dt_bias


def param_defs(cfg: ModelConfig) -> List[ParamDef]:
    D, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    defs: List[ParamDef] = [
        ParamDef(("embed",), (V, D), ("vocab", "residual")),
        ParamDef(("final_norm",), (D,), (None,), "ones"),
    ]
    if not cfg.tie_embeddings:
        defs.append(ParamDef(("lm_head",), (D, V), ("residual", "vocab")))

    Lx = (L,)
    lax_ = ("layers",)

    if cfg.has_attention:
        H, KV, hd = cfg.q_dim, cfg.kv_dim, cfg.head_dim
        defs += [
            ParamDef(("layers", "attn", "wq"), Lx + (D, H), lax_ + ("residual", "heads")),
            ParamDef(("layers", "attn", "wk"), Lx + (D, KV), lax_ + ("residual", "kv")),
            ParamDef(("layers", "attn", "wv"), Lx + (D, KV), lax_ + ("residual", "kv")),
            ParamDef(("layers", "attn", "wo"), Lx + (H, D), lax_ + ("heads", "residual"), "out_normal"),
            ParamDef(("layers", "ln1"), Lx + (D,), lax_ + (None,), "ones"),
        ]
        if cfg.qkv_bias:
            defs += [
                ParamDef(("layers", "attn", "bq"), Lx + (H,), lax_ + ("heads",), "zeros"),
                ParamDef(("layers", "attn", "bk"), Lx + (KV,), lax_ + ("kv",), "zeros"),
                ParamDef(("layers", "attn", "bv"), Lx + (KV,), lax_ + ("kv",), "zeros"),
            ]
        if cfg.qk_norm:
            defs += [
                ParamDef(("layers", "attn", "q_norm"), Lx + (hd,), lax_ + (None,), "ones"),
                ParamDef(("layers", "attn", "k_norm"), Lx + (hd,), lax_ + (None,), "ones"),
            ]

    if cfg.has_ssm:
        Di, R, N, K = cfg.d_inner, cfg.dt_rank, cfg.ssm_state, cfg.ssm_conv
        defs += [
            ParamDef(("layers", "ssm", "in_proj"), Lx + (D, 2 * Di), lax_ + ("residual", "dinner")),
            ParamDef(("layers", "ssm", "conv_w"), Lx + (Di, K), lax_ + ("dinner", None)),
            ParamDef(("layers", "ssm", "conv_b"), Lx + (Di,), lax_ + ("dinner",), "zeros"),
            ParamDef(("layers", "ssm", "x_proj"), Lx + (Di, R + 2 * N), lax_ + ("dinner", None)),
            ParamDef(("layers", "ssm", "dt_proj"), Lx + (R, Di), lax_ + (None, "dinner")),
            ParamDef(("layers", "ssm", "dt_bias"), Lx + (Di,), lax_ + ("dinner",), "dt_bias"),
            ParamDef(("layers", "ssm", "A_log"), Lx + (Di, N), lax_ + ("dinner", None), "ssm_A"),
            ParamDef(("layers", "ssm", "D"), Lx + (Di,), lax_ + ("dinner",), "ones"),
            ParamDef(("layers", "ssm", "out_proj"), Lx + (Di, D), lax_ + ("dinner", "residual"), "out_normal"),
        ]
        if cfg.family == "ssm":
            defs.append(ParamDef(("layers", "ln1"), Lx + (D,), lax_ + (None,), "ones"))

    if cfg.family == "hybrid":
        defs += [
            ParamDef(("layers", "attn_branch_norm"), Lx + (D,), lax_ + (None,), "ones"),
            ParamDef(("layers", "ssm_branch_norm"), Lx + (D,), lax_ + (None,), "ones"),
        ]

    if cfg.is_moe:
        E, Fm = cfg.num_experts, cfg.moe_d_ff
        defs += [
            ParamDef(("layers", "moe", "router"), Lx + (D, E), lax_ + ("residual", None)),
            ParamDef(("layers", "moe", "wi"), Lx + (E, D, Fm), lax_ + ("experts", "residual", "expert_ffn")),
            ParamDef(("layers", "moe", "wg"), Lx + (E, D, Fm), lax_ + ("experts", "residual", "expert_ffn")),
            ParamDef(("layers", "moe", "wo"), Lx + (E, Fm, D), lax_ + ("experts", "expert_ffn", "residual"), "out_normal"),
            ParamDef(("layers", "ln2"), Lx + (D,), lax_ + (None,), "ones"),
        ]
    elif cfg.d_ff > 0:
        F = cfg.d_ff
        defs += [
            ParamDef(("layers", "mlp", "wi"), Lx + (D, F), lax_ + ("residual", "ffn")),
            ParamDef(("layers", "mlp", "wg"), Lx + (D, F), lax_ + ("residual", "ffn")),
            ParamDef(("layers", "mlp", "wo"), Lx + (F, D), lax_ + ("ffn", "residual"), "out_normal"),
            ParamDef(("layers", "ln2"), Lx + (D,), lax_ + (None,), "ones"),
        ]
    return defs


def _set_path(tree: Dict, path: Tuple[str, ...], value) -> None:
    node = tree
    for k in path[:-1]:
        node = node.setdefault(k, {})
    node[path[-1]] = value


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> PyTree:
    """Real initialization (use only for reduced configs on CPU)."""
    params: Dict = {}
    defs = param_defs(cfg)
    keys = jax.random.split(key, len(defs))
    for d, k in zip(defs, keys):
        if d.init == "normal":
            v = jax.random.normal(k, d.shape, dtype) * 0.02
        elif d.init == "out_normal":
            v = jax.random.normal(k, d.shape, dtype) * (0.02 / np.sqrt(2 * cfg.num_layers))
        elif d.init == "zeros":
            v = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            v = jnp.ones(d.shape, dtype)
        elif d.init == "ssm_A":
            n = d.shape[-1]
            a = jnp.tile(jnp.log(jnp.arange(1, n + 1, dtype=dtype)), d.shape[:-1] + (1,))
            v = a
        elif d.init == "dt_bias":
            # inverse softplus of dt in [1e-3, 1e-1]
            dt = jnp.exp(
                jax.random.uniform(k, d.shape, dtype)
                * (np.log(0.1) - np.log(1e-3))
                + np.log(1e-3)
            )
            v = dt + jnp.log(-jnp.expm1(-dt))
        else:  # pragma: no cover
            raise ValueError(d.init)
        _set_path(params, d.path, v)
    return params


def abstract_params(cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    params: Dict = {}
    for d in param_defs(cfg):
        _set_path(params, d.path, jax.ShapeDtypeStruct(d.shape, dtype))
    return params


def logical_specs(cfg: ModelConfig) -> PyTree:
    specs: Dict = {}
    for d in param_defs(cfg):
        _set_path(specs, d.path, d.logical)
    return specs


def param_partition_specs(
    cfg: ModelConfig,
    rules: Dict[Optional[str], Optional[Any]],
    axis_sizes: Optional[Dict[str, int]] = None,
) -> PyTree:
    """Map logical axes -> mesh axes per ``rules`` (e.g. train FSDP+TP).

    Shape-aware: a dim whose size does not divide its mesh axis is left
    unsharded (jit argument shardings require even division), and a mesh
    axis claimed by two dims of the same tensor goes to the earlier dim
    (e.g. mixtral's E=8 cannot take ``model``=16, so the per-expert FFN
    dim inherits it; qwen3's E=128 can, so the FFN dim is dropped).
    """
    specs: Dict = {}
    for d in param_defs(cfg):
        axes = []
        used = set()
        for dim, logical in zip(d.shape, d.logical):
            ax = rules.get(logical, None)
            if ax is None:
                axes.append(None)
                continue
            sizes = [axis_sizes.get(a, 1) for a in (ax if isinstance(ax, tuple) else (ax,))] if axis_sizes else [1]
            total = 1
            for s in sizes:
                total *= s
            key = ax if isinstance(ax, tuple) else (ax,)
            if (axis_sizes is not None and dim % total != 0) or any(a in used for a in key):
                axes.append(None)
                continue
            used.update(key)
            axes.append(ax)
        _set_path(specs, d.path, P(*axes))
    return specs


# --------------------------------------------------------------------------- #
# Activation sharding bundle
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ActSharding:
    """PartitionSpecs for activation constraint points (None => unconstrained)."""

    residual: Optional[P] = None      # (B, S, D)
    logits: Optional[P] = None        # (B, chunk, V) inside the CE scan
    moe_tokens: Optional[P] = None    # (G, Tg, D) grouped tokens
    moe_buf: Optional[P] = None       # (G, E, C, D) dispatch buffer
    moe_groups: int = 1
    # §Perf: shard_map expert-parallel a2a (dict: mesh/batch_axes/model_axis/
    # seq_axis); None => global-view dispatch
    moe_a2a: Optional[Any] = None
    kv_cache: Optional[P] = None      # (L, B, S, KV, hd)
    decode_residual: Optional[P] = None  # (B, 1, D)

    def res(self, x):
        return constrain(x, self.residual) if self.residual is not None else x

    def dres(self, x):
        return constrain(x, self.decode_residual) if self.decode_residual is not None else x


NO_SHARDING = ActSharding()


# --------------------------------------------------------------------------- #
# Blocks
# --------------------------------------------------------------------------- #
def _attn_branch(
    cfg: ModelConfig,
    lp: Dict,
    h: jax.Array,
    positions: jax.Array,
    *,
    q_chunk: int,
) -> jax.Array:
    B, S, _ = h.shape
    ap = lp["attn"]
    q = jnp.einsum("bsd,dh->bsh", h, ap["wq"])
    k = jnp.einsum("bsd,dh->bsh", h, ap["wk"])
    v = jnp.einsum("bsd,dh->bsh", h, ap["wv"])
    if cfg.qkv_bias:
        q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm_headwise(q, ap["q_norm"], cfg.norm_eps)
        k = rms_norm_headwise(k, ap["k_norm"], cfg.norm_eps)
    q = apply_rope_cfg(cfg, q, positions)
    k = apply_rope_cfg(cfg, k, positions)
    out = attention_xla(
        q, k, v, causal=True, window=cfg.sliding_window, q_chunk=q_chunk
    )
    out = out.reshape(B, S, cfg.q_dim)
    return jnp.einsum("bsh,hd->bsd", out, ap["wo"]), (k, v)


def apply_rope_cfg(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    from repro.models.layers import apply_rope

    return apply_rope(x, positions, cfg.rope_theta)


def _ffn_branch(cfg: ModelConfig, lp: Dict, x: jax.Array, shardings: ActSharding):
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        mp = lp["moe"]
        if shardings.moe_a2a is not None:
            from repro.models.moe import moe_ffn_a2a

            y, aux = moe_ffn_a2a(
                x, mp["router"], mp["wi"], mp["wg"], mp["wo"],
                num_experts=cfg.num_experts,
                top_k=cfg.num_experts_per_tok,
                capacity_factor=cfg.capacity_factor,
                **shardings.moe_a2a,
            )
        else:
            y, aux = moe_ffn(
                x,
                mp["router"],
                mp["wi"],
                mp["wg"],
                mp["wo"],
                num_experts=cfg.num_experts,
                top_k=cfg.num_experts_per_tok,
                capacity_factor=cfg.capacity_factor,
                groups=shardings.moe_groups,
                token_spec=shardings.moe_tokens,
                buf_spec=shardings.moe_buf,
            )
    else:
        mp = lp["mlp"]
        y = swiglu_mlp(x, mp["wi"], mp["wg"], mp["wo"])
    return y, aux


def block_fwd(
    cfg: ModelConfig,
    x: jax.Array,
    lp: Dict,
    positions: jax.Array,
    shardings: ActSharding,
    *,
    q_chunk: int = 0,
    collect_cache: bool = False,
):
    """One decoder block. Returns (x, aux_loss, cache_kv | None)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    cache = None
    if cfg.family == "hybrid":
        attn_out, cache = _attn_branch(cfg, lp, h, positions, q_chunk=q_chunk)
        ssm_out = ssm_mod.mamba_block(
            h, lp["ssm"], dt_rank=cfg.dt_rank, ssm_state=cfg.ssm_state
        )
        mix = 0.5 * (
            rms_norm(attn_out, lp["attn_branch_norm"], cfg.norm_eps)
            + rms_norm(ssm_out, lp["ssm_branch_norm"], cfg.norm_eps)
        )
    elif cfg.family == "ssm":
        mix = ssm_mod.mamba_block(
            h, lp["ssm"], dt_rank=cfg.dt_rank, ssm_state=cfg.ssm_state
        )
    else:
        mix, cache = _attn_branch(cfg, lp, h, positions, q_chunk=q_chunk)
    x = shardings.res(x + mix)

    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe or cfg.d_ff > 0:
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, aux = _ffn_branch(cfg, lp, h2, shardings)
        x = shardings.res(x + y)
    if not collect_cache:
        cache = None
    return x, aux, cache


# --------------------------------------------------------------------------- #
# Forward (train / prefill)
# --------------------------------------------------------------------------- #
def forward_hidden(
    cfg: ModelConfig,
    params: PyTree,
    tokens: jax.Array,
    *,
    pixel_embeds: Optional[jax.Array] = None,
    shardings: ActSharding = NO_SHARDING,
    compute_dtype=jnp.bfloat16,
    q_chunk: int = 0,
    collect_cache: bool = False,
    remat: bool = True,
    remat_policy: Optional[str] = None,
):
    """Embed -> scan(blocks) -> final norm.

    Returns (hidden (B, S, D), aux_loss, cache (L,B,S,KV,hd)x2 | None).
    ``remat_policy``: None (save nothing, recompute all) | "dots" (save dot
    outputs — trades activation memory for recompute traffic; §Perf knob).
    """
    x = embed_tokens(params["embed"], tokens, compute_dtype)
    if cfg.vision_prefix and pixel_embeds is not None:
        x = jnp.concatenate([pixel_embeds.astype(compute_dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    x = shardings.res(x)

    layers = jax.tree.map(lambda p: p.astype(compute_dtype), params["layers"])

    def body_inner(x, lp):
        x, aux, cache = block_fwd(
            cfg, x, lp, positions, shardings,
            q_chunk=q_chunk, collect_cache=collect_cache,
        )
        return x, aux, cache

    if remat and remat_policy == "dots":
        wrapped = jax.checkpoint(
            body_inner,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    elif remat:
        wrapped = jax.checkpoint(body_inner)
    else:
        wrapped = body_inner

    def body(carry, lp):
        x, aux_sum = carry
        x, aux, cache = wrapped(x, lp)
        return (x, aux_sum + aux), cache

    (x, aux_sum), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_sum, caches


def lm_head_weight(cfg: ModelConfig, params: PyTree) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def loss_fn(
    cfg: ModelConfig,
    params: PyTree,
    batch: Dict[str, jax.Array],
    *,
    shardings: ActSharding = NO_SHARDING,
    compute_dtype=jnp.bfloat16,
    aux_weight: float = 0.01,
    q_chunk: int = 0,
    ce_chunk: int = 512,
    remat_policy: Optional[str] = None,
):
    """Next-token cross-entropy (+ MoE aux). batch: tokens, labels, mask."""
    hidden, aux, _ = forward_hidden(
        cfg,
        params,
        batch["tokens"],
        pixel_embeds=batch.get("pixel_embeds"),
        shardings=shardings,
        compute_dtype=compute_dtype,
        q_chunk=q_chunk,
        remat_policy=remat_policy,
    )
    head = lm_head_weight(cfg, params).astype(compute_dtype)
    labels = batch["labels"]
    mask = batch["mask"].astype(jnp.float32)
    if cfg.vision_prefix:
        # loss only over text positions; vision prefix is unsupervised
        hidden = hidden[:, cfg.vision_prefix :]
    nll_sum, n_tok = cross_entropy_chunked(
        hidden, head, labels, mask, chunk=ce_chunk, logits_spec=shardings.logits
    )
    loss = nll_sum / jnp.maximum(n_tok, 1.0)
    total = loss + aux_weight * aux / max(cfg.num_layers, 1)
    metrics = {"loss": loss, "aux_loss": aux, "tokens": n_tok}
    return total, metrics


# --------------------------------------------------------------------------- #
# Prefill
# --------------------------------------------------------------------------- #
def prefill(
    cfg: ModelConfig,
    params: PyTree,
    tokens: jax.Array,
    *,
    pixel_embeds: Optional[jax.Array] = None,
    shardings: ActSharding = NO_SHARDING,
    compute_dtype=jnp.bfloat16,
    q_chunk: int = 1024,
):
    """Returns (last-position logits (B, V), cache)."""
    hidden, _, caches = forward_hidden(
        cfg,
        params,
        tokens,
        pixel_embeds=pixel_embeds,
        shardings=shardings,
        compute_dtype=compute_dtype,
        q_chunk=q_chunk,
        collect_cache=cfg.has_attention,
        remat=False,
    )
    head = lm_head_weight(cfg, params).astype(compute_dtype)
    logits = jnp.einsum("bd,dv->bv", hidden[:, -1], head).astype(jnp.float32)
    cache = None
    if cfg.has_attention and caches is not None:
        k, v = caches
        if shardings.kv_cache is not None:
            k = constrain(k, shardings.kv_cache)
            v = constrain(v, shardings.kv_cache)
        cache = {"k": k, "v": v}
    return logits, cache


# --------------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------------- #
def make_decode_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> PyTree:
    """Zero-initialized decode cache.

    ``dtype=jnp.int8`` stores quantized K/V with per-(position, kv-head)
    fp32 scales — halves the dominant decode-HBM term (§Perf); dequant
    happens per attention call (fused into the kernel's VMEM tiles on TPU).
    """
    cache: Dict[str, Any] = {}
    L = cfg.num_layers
    if cfg.has_attention:
        shape = (L, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
        cache["k"] = jnp.zeros(shape, dtype)
        cache["v"] = jnp.zeros(shape, dtype)
        if dtype == jnp.int8:
            sshape = (L, batch, max_seq, cfg.num_kv_heads)
            cache["k_scale"] = jnp.ones(sshape, jnp.float32)
            cache["v_scale"] = jnp.ones(sshape, jnp.float32)
    if cfg.has_ssm:
        cache["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.bfloat16)
        cache["ssm"] = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
    return cache


def abstract_decode_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> PyTree:
    return jax.eval_shape(lambda: make_decode_cache(cfg, batch, max_seq, dtype))


def _decode_block(
    cfg: ModelConfig,
    x: jax.Array,
    lp: Dict,
    cl: Dict,
    cur_index: jax.Array,
    shardings: ActSharding,
):
    """x: (B,1,D); cl: per-layer cache slices. Returns (x, new_cl)."""
    B = x.shape[0]
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    new_cl: Dict[str, jax.Array] = {}
    pos = jnp.full((B, 1), cur_index, dtype=jnp.int32)

    quantized = "k_scale" in cl

    def attn(h):
        ap = lp["attn"]
        q = jnp.einsum("bsd,dh->bsh", h, ap["wq"])
        k = jnp.einsum("bsd,dh->bsh", h, ap["wk"])
        v = jnp.einsum("bsd,dh->bsh", h, ap["wv"])
        if cfg.qkv_bias:
            q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
        q = q.reshape(B, 1, cfg.num_heads, cfg.head_dim)
        k = k.reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
        v = v.reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_norm_headwise(q, ap["q_norm"], cfg.norm_eps)
            k = rms_norm_headwise(k, ap["k_norm"], cfg.norm_eps)
        q = apply_rope_cfg(cfg, q, pos)
        k = apply_rope_cfg(cfg, k, pos)
        new_scales = {}
        if quantized:
            # per-(position, kv-head) int8 quantization of the new K/V
            def quant(t):
                scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
                scale = jnp.maximum(scale, 1e-8)
                q8 = jnp.clip(
                    jnp.round(t.astype(jnp.float32) / scale[..., None]), -127, 127
                ).astype(jnp.int8)
                return q8, scale
            k, ks = quant(k)
            v, vs = quant(v)
            new_scales["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cl["k_scale"], ks, cur_index, axis=1)
            new_scales["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cl["v_scale"], vs, cur_index, axis=1)
        kc = jax.lax.dynamic_update_slice_in_dim(cl["k"], k, cur_index, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cl["v"], v, cur_index, axis=1)
        if quantized:
            k_use = (kc.astype(jnp.float32)
                     * new_scales["k_scale"][..., None]).astype(jnp.bfloat16)
            v_use = (vc.astype(jnp.float32)
                     * new_scales["v_scale"][..., None]).astype(jnp.bfloat16)
        else:
            k_use, v_use = kc, vc
        out = decode_attention_xla(q.astype(k_use.dtype), k_use, v_use,
                                   cur_index, window=cfg.sliding_window)
        out = out.reshape(B, 1, cfg.q_dim)
        return jnp.einsum("bsh,hd->bsd", out.astype(h.dtype), ap["wo"]), kc, vc, new_scales

    def ssm_step(h):
        return ssm_mod.mamba_decode_step(
            h, lp["ssm"], cl["conv"], cl["ssm"],
            dt_rank=cfg.dt_rank, ssm_state=cfg.ssm_state,
        )

    if cfg.family == "hybrid":
        attn_out, kc, vc, scales = attn(h)
        ssm_out, conv_s, ssm_s = ssm_step(h)
        new_cl.update(k=kc, v=vc, conv=conv_s, ssm=ssm_s, **scales)
        mix = 0.5 * (
            rms_norm(attn_out, lp["attn_branch_norm"], cfg.norm_eps)
            + rms_norm(ssm_out, lp["ssm_branch_norm"], cfg.norm_eps)
        )
    elif cfg.family == "ssm":
        mix, conv_s, ssm_s = ssm_step(h)
        new_cl.update(conv=conv_s, ssm=ssm_s)
    else:
        mix, kc, vc, scales = attn(h)
        new_cl.update(k=kc, v=vc, **scales)
    x = shardings.dres(x + mix)

    if cfg.is_moe or cfg.d_ff > 0:
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, _ = _ffn_branch(cfg, lp, h2, shardings)
        x = shardings.dres(x + y)
    return x, new_cl


def decode_step(
    cfg: ModelConfig,
    params: PyTree,
    cache: PyTree,
    tokens: jax.Array,
    cur_index: jax.Array,
    *,
    shardings: ActSharding = NO_SHARDING,
    compute_dtype=jnp.bfloat16,
):
    """One token for every sequence. tokens: (B, 1) -> (logits (B,V), cache)."""
    x = embed_tokens(params["embed"], tokens, compute_dtype)
    x = shardings.dres(x)
    layers = jax.tree.map(lambda p: p.astype(compute_dtype), params["layers"])
    cache_f = jax.tree.map(lambda c: c, cache)

    def body(x, inp):
        lp, cl = inp
        x, new_cl = _decode_block(cfg, x, lp, cl, cur_index, shardings)
        return x, new_cl

    x, new_cache = jax.lax.scan(body, x, (layers, cache_f))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = lm_head_weight(cfg, params).astype(compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0].astype(jnp.float32)
    return logits, new_cache
