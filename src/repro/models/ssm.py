"""Mamba-1 selective SSM block (xla reference path).

Training/prefill uses a chunked scan: a sequential ``lax.scan`` over sequence
chunks carrying the (B, D_inner, N) state, with an associative scan inside
each chunk — this bounds the materialized (B, chunk, D_inner, N) tensors
(the same chunking scheme the Pallas ``ssm_scan`` kernel implements with
VMEM tiles). Decode is the O(1) single-step recurrence.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, Di); w: (Di, K); b: (Di,)."""
    K = w.shape[1]
    out = jnp.zeros_like(x)
    for i in range(K):
        shift = K - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * w[:, i][None, None, :]
    return out + b[None, None, :]


def _ssm_params(x: jax.Array, p: Dict[str, jax.Array], dt_rank: int, n: int):
    """x: (B, S, Di) -> dt (B,S,Di) fp32, B_ (B,S,N) fp32, C (B,S,N) fp32."""
    proj = jnp.einsum("bsd,dr->bsr", x, p["x_proj"]).astype(jnp.float32)
    dt_in, B_, C = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_in, p["dt_proj"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32)[None, None, :])
    return dt, B_, C


def _discretize(dt, B_, x, A):
    """dt: (B,S,Di); B_: (B,S,N); x: (B,S,Di); A: (Di,N) negative.

    Returns Abar (B,S,Di,N), Bx (B,S,Di,N) in fp32.
    """
    Abar = jnp.exp(dt[..., None] * A[None, None])             # (B,S,Di,N)
    Bx = dt[..., None] * B_[..., None, :] * x.astype(jnp.float32)[..., None]
    return Abar, Bx


def _chunk_scan(Abar, Bx, h0):
    """Associative scan within a chunk, seeded with carry state h0.

    Abar/Bx: (B, c, Di, N); h0: (B, Di, N). Returns (h_all (B,c,Di,N), h_last).
    """
    def combine(a, b):
        a_l, b_l = a
        a_r, b_r = b
        return a_l * a_r, b_l * a_r + b_r

    Aacc, Bacc = jax.lax.associative_scan(combine, (Abar, Bx), axis=1)
    h_all = Aacc * h0[:, None] + Bacc
    return h_all, h_all[:, -1]


def selective_scan(
    x: jax.Array,
    dt: jax.Array,
    B_: jax.Array,
    C: jax.Array,
    A: jax.Array,
    D: jax.Array,
    *,
    chunk: int = 1024,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """y = SSM(x) with selective (input-dependent) dynamics.

    x: (B, S, Di); dt: (B, S, Di); B_/C: (B, S, N); A: (Di, N) (negative);
    D: (Di,) skip. Returns (y (B,S,Di) in x.dtype, h_last (B,Di,N) fp32).
    """
    Bsz, S, Di = x.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, Di, N), jnp.float32)

    c = min(chunk, S)
    while S % c:
        c -= 1
    n_chunks = S // c

    if n_chunks == 1:
        Abar, Bx = _discretize(dt, B_, x, A)
        h_all, h_last = _chunk_scan(Abar, Bx, h0)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, C)
    else:
        # Discretize INSIDE the chunk so (B, c, Di, N) tensors never
        # materialize for the full sequence (and remat recomputes them in
        # the backward pass instead of saving them).
        def split(t):
            return t.reshape(Bsz, n_chunks, c, *t.shape[2:]).swapaxes(0, 1)

        x_c, dt_c, B_c, C_c = split(x), split(dt), split(B_), split(C)

        @jax.checkpoint
        def chunk_fn(h, xc, dtc, Bc, Cc):
            Abar, Bx = _discretize(dtc, Bc, xc, A)
            h_all, h_last = _chunk_scan(Abar, Bx, h)
            yc = jnp.einsum("bsdn,bsn->bsd", h_all, Cc)
            return h_last, yc

        def body(h, inp):
            xc, dtc, Bc, Cc = inp
            return chunk_fn(h, xc, dtc, Bc, Cc)

        h_last, ys = jax.lax.scan(body, h0, (x_c, dt_c, B_c, C_c))
        y = ys.swapaxes(0, 1).reshape(Bsz, S, Di)

    y = y + x.astype(jnp.float32) * D[None, None, :]
    return y.astype(x.dtype), h_last


def mamba_block(
    x: jax.Array,
    p: Dict[str, jax.Array],
    *,
    dt_rank: int,
    ssm_state: int,
    chunk: int = 256,
) -> jax.Array:
    """Full mamba-1 mixer. x: (B, S, D) -> (B, S, D)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)  # (B,S,Di) each
    xi = causal_conv1d(xi, p["conv_w"], p["conv_b"])
    xi = xi * jax.nn.sigmoid(xi.astype(jnp.float32)).astype(xi.dtype)  # silu
    dt, B_, C = _ssm_params(xi, p, dt_rank, ssm_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = selective_scan(xi, dt, B_, C, A, p["D"].astype(jnp.float32), chunk=chunk)
    y = y * (z * jax.nn.sigmoid(z.astype(jnp.float32)).astype(z.dtype))  # gate
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


# --------------------------------------------------------------------------- #
# Decode (single-step recurrence)
# --------------------------------------------------------------------------- #
def mamba_decode_step(
    x: jax.Array,
    p: Dict[str, jax.Array],
    conv_state: jax.Array,
    ssm_state_v: jax.Array,
    *,
    dt_rank: int,
    ssm_state: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, 1, D); conv_state: (B, K-1, Di); ssm_state_v: (B, Di, N).

    Returns (y (B,1,D), new_conv_state, new_ssm_state).
    """
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)          # (B,1,Di)
    K = p["conv_w"].shape[1]
    window = jnp.concatenate([conv_state, xi], axis=1)      # (B,K,Di)
    conv = jnp.einsum("bkd,dk->bd", window, p["conv_w"]) + p["conv_b"]
    conv = conv[:, None, :]                                  # (B,1,Di)
    conv = conv * jax.nn.sigmoid(conv.astype(jnp.float32)).astype(conv.dtype)
    dt, B_, C = _ssm_params(conv, p, dt_rank, ssm_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    Abar = jnp.exp(dt[:, 0, :, None] * A[None])              # (B,Di,N)
    Bx = dt[:, 0, :, None] * B_[:, 0, None, :] * conv.astype(jnp.float32)[:, 0, :, None]
    h = Abar * ssm_state_v + Bx                              # (B,Di,N)
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0]) + conv.astype(jnp.float32)[:, 0] * p["D"].astype(jnp.float32)[None]
    y = y.astype(x.dtype)[:, None, :]
    y = y * (z * jax.nn.sigmoid(z.astype(jnp.float32)).astype(z.dtype))
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, window[:, 1:], h
