"""Transactional training: every step is a function-grained transaction.

The FaaS execution model mapped onto training workers:

  * a worker BEGINs a transaction, reads the current parameter version
    (block-cached; only changed blocks cross the wire — eager/lazy policy),
  * runs the jit'd ``train_step`` (pure JAX; pjit-sharded on real meshes),
  * COMMITs the parameter delta blocks + a step-counter increment.

OCC consequences, exactly the paper's:

  * concurrent workers that touched disjoint parameter partitions commit
    independently (TPC-C warehouses == parameter partitions),
  * a conflicting commit aborts and the step retries on fresh state
    (function-grained fault tolerance; also the straggler story — a backup
    worker can race the same step and the loser aborts harmlessly),
  * a worker that dies mid-step leaves no partial state (atomicity).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.client import LocalServer
from repro.core.posix import FaaSFS, O_CREAT
from repro.core.runtime import FunctionRuntime, InvocationStats
from repro.core.tensorstate import TensorStore, flatten_with_names, unflatten_like

PyTree = Any


@dataclass
class StepResult:
    step: int
    metrics: Dict[str, float]
    attempts: int
    commit_ts: int
    bytes_written: int


@dataclass
class WorkerStats:
    steps: int = 0
    aborts: int = 0
    commit_bytes: int = 0
    wall_s: float = 0.0


class TransactionalTrainer:
    """Drives train steps as FaaSFS transactions against shared state.

    ``partition`` optionally names the parameter subtree this worker updates
    (data-parallel workers updating disjoint shards — the high-concurrency
    regime; ``None`` = whole model per step, the contended regime).
    """

    def __init__(
        self,
        local: LocalServer,
        train_step: Callable[[PyTree, Any], tuple],
        template: PyTree,
        *,
        root: str = "/mnt/tsfs/train",
        name: str = "state",
    ):
        self.local = local
        self.train_step = train_step
        self.template = template
        self.root = root.rstrip("/")
        self.name = name
        self.stats = WorkerStats()
        self._runtime = FunctionRuntime(local)

    # ------------------------------------------------------------------ #
    def init(self, state: PyTree) -> int:
        def do_init(fs: FaaSFS) -> None:
            store = TensorStore(fs, prefix=self.root)
            store.save(self.name, state)
            fd = fs.open(f"{self.root}/{self.name}.step", O_CREAT)
            fs.pwrite(fd, (0).to_bytes(8, "little"), 0)
            fs.close(fd)

        inv = InvocationStats()
        self._runtime.invoke(do_init, stats=inv)
        return inv.commit_ts

    # ------------------------------------------------------------------ #
    def step(self, batch: Any) -> StepResult:
        """One training step as one transaction (with OCC retry inside)."""
        t0 = time.perf_counter()
        holder: Dict[str, Any] = {}

        def do_step(fs: FaaSFS) -> None:
            store = TensorStore(fs, prefix=self.root)
            flat = store.load(self.name)
            state = unflatten_like(self.template, flat)
            new_state, metrics = self.train_step(state, batch)
            new_state = jax.tree.map(np.asarray, new_state)
            s = store.save(self.name, new_state, baseline=flat)
            fd = fs.open(f"{self.root}/{self.name}.step")
            cur = int.from_bytes(fs.pread(fd, 8, 0), "little")
            fs.pwrite(fd, (cur + 1).to_bytes(8, "little"), 0)
            fs.close(fd)
            holder["metrics"] = {
                k: float(v) for k, v in metrics.items()
            }
            holder["step"] = cur + 1
            holder["bytes"] = s["bytes_written"]

        inv = InvocationStats()
        self._runtime.invoke(do_step, stats=inv)
        self.stats.steps += 1
        self.stats.aborts += inv.aborts
        self.stats.commit_bytes += holder.get("bytes", 0)
        self.stats.wall_s += time.perf_counter() - t0
        return StepResult(
            step=holder.get("step", -1),
            metrics=holder.get("metrics", {}),
            attempts=inv.attempts,
            commit_ts=inv.commit_ts,
            bytes_written=holder.get("bytes", 0),
        )

    # ------------------------------------------------------------------ #
    def read_state(self, snapshot: bool = True) -> PyTree:
        holder: Dict[str, Any] = {}

        def do_read(fs: FaaSFS) -> None:
            store = TensorStore(fs, prefix=self.root)
            holder["flat"] = store.load(self.name)

        self._runtime.invoke(do_read, read_only=snapshot)
        return unflatten_like(self.template, holder["flat"])
