"""Elastic membership via OCC topology predicates — no barriers, no leases.

The paper's file-length predicate generalizes: a cluster 'topology' file
records (generation, num_workers, partition map). Every training step reads
it (adding it to the read set); scale-up/down is a normal transaction that
bumps the generation. In-flight steps from the old generation then FAIL
VALIDATION at commit and retry against the new topology — the paper's
optimistic lock elision applied to cluster membership, instead of the
lease/barrier dance shared filesystems (and classic trainers) use.

Straggler mitigation falls out of the same mechanism: a backup worker may
race the same logical step; whichever commits first wins, the other aborts
at validation and moves on (at-most-once effects without coordination).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.client import LocalServer
from repro.core.posix import FaaSFS, O_CREAT, O_TRUNC
from repro.core.runtime import FunctionRuntime

TOPOLOGY_PATH = "/mnt/tsfs/cluster/topology"


@dataclass
class Topology:
    generation: int
    workers: List[str]
    partitions: Dict[str, List[str]]  # worker -> parameter partitions

    def to_bytes(self) -> bytes:
        return json.dumps(
            {"generation": self.generation, "workers": self.workers,
             "partitions": self.partitions}
        ).encode()

    @staticmethod
    def from_bytes(raw: bytes) -> "Topology":
        d = json.loads(raw)
        return Topology(d["generation"], d["workers"], d["partitions"])


class ElasticCoordinator:
    def __init__(self, local: LocalServer, path: str = TOPOLOGY_PATH):
        self.local = local
        self.path = path
        self._runtime = FunctionRuntime(local)

    # ------------------------------------------------------------------ #
    def bootstrap(self, workers: List[str], partitions: Dict[str, List[str]]) -> None:
        topo = Topology(1, workers, partitions)

        def do(fs: FaaSFS) -> None:
            fd = fs.open(self.path, O_CREAT | O_TRUNC)
            fs.write(fd, topo.to_bytes())
            fs.close(fd)

        self._runtime.invoke(do)

    def read(self, fs: FaaSFS) -> Topology:
        """Read topology INSIDE a step's transaction: joins the read set, so
        any membership change aborts this step at commit."""
        fd = fs.open(self.path)
        n = fs.fstat(fd)["st_size"]
        raw = fs.pread(fd, n, 0)
        fs.close(fd)
        return Topology.from_bytes(raw)

    # ------------------------------------------------------------------ #
    def _rewrite(self, mutate) -> Topology:
        out: Dict[str, Topology] = {}

        def do(fs: FaaSFS) -> None:
            topo = self.read(fs)
            topo = mutate(topo)
            topo.generation += 1
            fd = fs.open(self.path, O_TRUNC)
            fs.write(fd, topo.to_bytes())
            fs.close(fd)
            out["topo"] = topo

        self._runtime.invoke(do)
        return out["topo"]

    def join(self, worker: str, partitions: Optional[List[str]] = None) -> Topology:
        def mutate(t: Topology) -> Topology:
            if worker not in t.workers:
                t.workers.append(worker)
            t.partitions[worker] = partitions or []
            return t

        return self._rewrite(mutate)

    def leave(self, worker: str) -> Topology:
        def mutate(t: Topology) -> Topology:
            t.workers = [w for w in t.workers if w != worker]
            orphaned = t.partitions.pop(worker, [])
            # reassign orphaned partitions round-robin (restart-free rebalance)
            for i, p in enumerate(orphaned):
                if t.workers:
                    t.partitions.setdefault(t.workers[i % len(t.workers)], []).append(p)
            return t

        return self._rewrite(mutate)
