"""Block-delta Pallas kernel: the paper's fine-grained change tracking,
computed on-device.

Given the new and previous values of a flat parameter buffer laid out in
FaaSFS blocks, one grid step per block computes, entirely in VMEM:

  * the block's delta L2 norm^2 (dirty detection / significance),
  * the block's max-abs (int8 quantization scale),
  * the int8-quantized delta.

The commit path then ships only blocks whose norm clears a threshold, as
int8 + one fp32 scale — the paper's block-granular cache-update protocol
doubling as gradient/update compression (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _delta_kernel(new_ref, old_ref, q_ref, norm_ref, scale_ref):
    new = new_ref[...].astype(jnp.float32)      # (1, block)
    old = old_ref[...].astype(jnp.float32)
    diff = new - old
    norm2 = jnp.sum(diff * diff)
    maxabs = jnp.max(jnp.abs(diff))
    scale = jnp.where(maxabs > 0, maxabs / 127.0, 1.0)
    q = jnp.clip(jnp.round(diff / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    norm_ref[0, 0] = norm2
    scale_ref[0, 0] = scale


def block_delta(
    new: jax.Array,      # (nblocks, block_elems)
    old: jax.Array,      # (nblocks, block_elems)
    *,
    interpret: bool = False,
):
    """Returns (q int8 (nblocks, block_elems), norm2 (nblocks,), scale (nblocks,))."""
    nb, be = new.shape
    q, norm2, scale = pl.pallas_call(
        _delta_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, be), lambda i: (i, 0)),
            pl.BlockSpec((1, be), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, be), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, be), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(new, old)
    return q, norm2[:, 0], scale[:, 0]
