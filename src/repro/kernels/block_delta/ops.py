"""Jit'd public wrapper for the block-delta kernel + host-side helpers."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_delta.kernel import block_delta
from repro.kernels.block_delta.ref import apply_delta_ref, block_delta_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def compute_block_delta(new: jax.Array, old: jax.Array, *, impl: str = "pallas"):
    """new/old: (nblocks, block_elems) -> (q int8, norm2 f32, scale f32)."""
    if impl == "xla":
        return block_delta_ref(new, old)
    return block_delta(new, old, interpret=(impl == "pallas_interpret"))


def pack_dirty(
    q: np.ndarray, norm2: np.ndarray, scale: np.ndarray, threshold: float = 0.0
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Select blocks whose delta norm^2 clears ``threshold``.

    Returns (dirty_indices, q_dirty, scales_dirty) — what a commit ships.
    """
    idx = np.flatnonzero(np.asarray(norm2) > threshold)
    return idx, np.asarray(q)[idx], np.asarray(scale)[idx]


def blockify(flat: np.ndarray, block_elems: int) -> np.ndarray:
    """Pad a flat array to a whole number of blocks and reshape."""
    n = len(flat)
    nb = -(-n // block_elems)
    out = np.zeros((nb * block_elems,), flat.dtype)
    out[:n] = flat
    return out.reshape(nb, block_elems)
