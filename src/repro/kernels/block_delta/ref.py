"""Pure-jnp oracle for the block-delta kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_delta_ref(new: jax.Array, old: jax.Array):
    diff = new.astype(jnp.float32) - old.astype(jnp.float32)
    norm2 = jnp.sum(diff * diff, axis=1)
    maxabs = jnp.max(jnp.abs(diff), axis=1)
    scale = jnp.where(maxabs > 0, maxabs / 127.0, 1.0)
    q = jnp.clip(jnp.round(diff / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, norm2, scale


def apply_delta_ref(old: jax.Array, q: jax.Array, scale: jax.Array) -> jax.Array:
    """Dequantize + apply: reconstruct new params from the shipped delta."""
    return (old.astype(jnp.float32) + q.astype(jnp.float32) * scale[:, None]).astype(old.dtype)
