"""Jit'd public wrapper for the selective-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "block_d"))
def selective_scan(
    x, dt, b, c, a_log, d,
    *,
    impl: str = "pallas",       # pallas | pallas_interpret | xla
    chunk: int = 128,
    block_d: int = 256,
):
    if impl == "xla":
        return ssm_scan_ref(x, dt, b, c, a_log, d)
    return ssm_scan(
        x, dt, b, c, a_log, d,
        chunk=chunk, block_d=block_d,
        interpret=(impl == "pallas_interpret"),
    )
