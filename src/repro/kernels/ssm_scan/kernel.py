"""Selective-scan (mamba-1) Pallas kernel for TPU.

Tiling: grid = (batch, d_inner_blocks, seq_chunks) with the sequence-chunk
axis LAST, so the (block_d, N) hidden state lives in VMEM scratch and
carries across chunks — HBM sees x/dt/B/C exactly once and never the
(S, d_inner, N) discretized tensors the pure-jnp path materializes.

Inside a chunk the recurrence h_t = exp(dt_t*A) h_{t-1} + dt_t*x_t*B_t is
stepped sequentially (VPU elementwise (block_d, N) work + an (N,) matvec
per step); the chunk-parallel SSD formulation that trades this for MXU
matmuls is the recorded next §Perf iteration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(
    x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref,   # tiles
    y_ref,                                        # (1, chunk, block_d)
    h_scr,                                        # (block_d, N) f32
    *,
    chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = -jnp.exp(a_ref[...].astype(jnp.float32))          # (block_d, N)
    dskip = d_ref[...].astype(jnp.float32)                # (1, block_d)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)           # (block_d,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)         # (block_d,)
        bt = b_ref[0, t, :].astype(jnp.float32)           # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)           # (N,)
        abar = jnp.exp(dtt[:, None] * a)                  # (block_d, N)
        bx = (dtt * xt)[:, None] * bt[None, :]            # (block_d, N)
        h = abar * h + bx
        yt = jnp.sum(h * ct[None, :], axis=1) + dskip[0] * xt
        y_ref[0, t, :] = yt.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h


def ssm_scan(
    x: jax.Array,       # (B, S, Di)
    dt: jax.Array,      # (B, S, Di)   (already softplus'd)
    b: jax.Array,       # (B, S, N)
    c: jax.Array,       # (B, S, N)
    a_log: jax.Array,   # (Di, N)
    d: jax.Array,       # (Di,)
    *,
    chunk: int = 128,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, S, Di = x.shape
    N = a_log.shape[1]
    chunk = min(chunk, S)
    block_d = min(block_d, Di)
    assert S % chunk == 0 and Di % block_d == 0, (S, chunk, Di, block_d)
    nc, nd = S // chunk, Di // block_d

    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, N), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((block_d, N), lambda bi, di, ci: (di, 0)),
            pl.BlockSpec((1, block_d), lambda bi, di, ci: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
        out_shape=jax.ShapeDtypeStruct((B, S, Di), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a_log, d.reshape(1, Di))
