"""Pure-jnp oracle for the selective-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(
    x: jax.Array,       # (B, S, Di)
    dt: jax.Array,      # (B, S, Di)
    b: jax.Array,       # (B, S, N)
    c: jax.Array,       # (B, S, N)
    a_log: jax.Array,   # (Di, N)
    d: jax.Array,       # (Di,)
) -> jax.Array:
    a = -jnp.exp(a_log.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    abar = jnp.exp(dtf[..., None] * a[None, None])               # (B,S,Di,N)
    bx = (dtf * xf)[..., None] * b.astype(jnp.float32)[:, :, None, :]

    def step(h, inp):
        ab, bx_t, c_t = inp
        h = ab * h + bx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    B, S, Di = x.shape
    N = a_log.shape[1]
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (abar.swapaxes(0, 1), bx.swapaxes(0, 1),
         c.astype(jnp.float32).swapaxes(0, 1)),
    )
    y = ys.swapaxes(0, 1) + xf * d.astype(jnp.float32)[None, None]
    return y.astype(x.dtype)
