"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,          # (B, H, Sq, hd)
    k: jax.Array,          # (B, KV, Sk, hd)
    v: jax.Array,          # (B, KV, Sk, hd)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    # guard fully-masked rows (all NEG_INF) to match kernel semantics
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    p = p / l
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, hd).astype(q.dtype)
