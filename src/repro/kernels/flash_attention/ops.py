"""Jit'd public wrapper: layout adaptation + impl dispatch.

Model code uses (B, S, H, hd); the kernel wants (B, H, S, hd) with the
sequence on the second-minor axis (MXU-friendly contiguous tiles).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "impl", "block_q", "block_k")
)
def attention(
    q: jax.Array,          # (B, S, H, hd)
    k: jax.Array,          # (B, S, KV, hd)
    v: jax.Array,          # (B, S, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,
    impl: str = "pallas",  # pallas | pallas_interpret | xla
    block_q: int = 128,
    block_k: int = 256,
) -> jax.Array:
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    if impl == "xla":
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        out = flash_attention(
            qt, kt, vt,
            causal=causal, window=window,
            block_q=block_q, block_k=block_k,
            interpret=(impl == "pallas_interpret"),
        )
    return out.swapaxes(1, 2)
