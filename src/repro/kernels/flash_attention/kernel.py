"""Blockwise (flash) attention Pallas kernel for TPU.

Design (TPU-native, not a CUDA port):
  * grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the LAST grid dim
    iterates sequentially on TPU, so VMEM scratch (running max / sum /
    accumulator) carries across kv blocks — the online-softmax recurrence,
  * BlockSpecs tile Q as (Bq, head_dim) and K/V as (Bk, head_dim) in VMEM;
    Bq/Bk default to 128/256 (MXU-aligned multiples of 128),
  * GQA folds into the K/V index_map (q head h reads kv head h // group),
  * causal + sliding-window masks are applied with 2-D iota inside the
    block; fully-masked blocks skip their matmuls via ``pl.when``,
  * softmax statistics are fp32; the QK^T and PV matmuls accumulate fp32
    via ``preferred_element_type`` feeding the MXU.

HBM traffic is O(S*d) per head instead of O(S^2): the score matrix never
leaves VMEM — this is what the roofline §Perf pass measures against the
materializing XLA path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref,        # VMEM tiles (1, 1, Bq|Bk, hd)
    o_ref,                      # output tile (1, 1, Bq, hd)
    m_scr, l_scr, acc_scr,      # scratch: (Bq, 1), (Bq, 1), (Bq, hd)
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    def compute():
        q = q_ref[0, 0]                                   # (Bq, hd)
        k = k_ref[0, 0]                                   # (Bk, hd)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                          # (Bq, Bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                                # (Bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (Bq, Bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_scr[...] * alpha
        acc = acc + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    if causal or window > 0:
        # block-level reachability — skip fully-masked tiles entirely
        lo_ok = True if not causal else (k_start <= q_start + block_q - 1)
        hi_ok = True if window <= 0 else (k_start + block_k - 1 > q_start - window)
        pl.when(jnp.logical_and(lo_ok, hi_ok))(compute)
    else:
        compute()

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                    # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,          # (B, H, Sq, hd)
    k: jax.Array,          # (B, KV, Sk, hd)
    v: jax.Array,          # (B, KV, Sk, hd)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
