"""Deterministic, shardable, resumable synthetic LM data pipeline.

Batches are a pure function of (seed, step, shard) — no filesystem state is
required to be local, so any worker can take over any shard at any step
(the statelessness the FaaS model assumes). The *cursor* (next step per
shard) lives in FaaSFS, so pipeline progress commits atomically with the
training step that consumed the batch: a retried step re-reads the same
cursor and regenerates the identical batch (exactly-once consumption).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.posix import FaaSFS, O_CREAT


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 1
    seed: int = 0


def _rng_for(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    key = f"{cfg.seed}:{step}:{shard}".encode()
    digest = hashlib.sha256(key).digest()
    return np.random.default_rng(np.frombuffer(digest[:8], dtype=np.uint64)[0])


def synth_batch(cfg: DataConfig, step: int, shard: int = 0) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens with enough structure to be learnable."""
    rng = _rng_for(cfg, step, shard)
    b = cfg.global_batch // cfg.num_shards
    s = cfg.seq_len
    # piecewise-repeating structure: short motifs the model can learn
    motif_len = 8
    n_motifs = 64
    motifs = (
        _rng_for(cfg, -1, 0).integers(0, cfg.vocab_size, (n_motifs, motif_len))
    )
    idx = rng.integers(0, n_motifs, (b, s // motif_len + 1))
    tokens = motifs[idx].reshape(b, -1)[:, :s].astype(np.int32)
    noise = rng.random((b, s)) < 0.05
    tokens = np.where(noise, rng.integers(0, cfg.vocab_size, (b, s)), tokens)
    labels = np.roll(tokens, -1, axis=1)
    mask = np.ones((b, s), np.float32)
    mask[:, -1] = 0.0
    return {
        "tokens": tokens,
        "labels": labels.astype(np.int32),
        "mask": mask,
    }


class PipelineCursor:
    """Per-shard next-step cursor stored in FaaSFS (atomic with the step)."""

    def __init__(self, path: str = "/mnt/tsfs/data/cursor"):
        self.path = path

    def next_step(self, fs: FaaSFS, shard: int) -> int:
        p = f"{self.path}.{shard}"
        fd = fs.open(p, O_CREAT)
        raw = fs.pread(fd, 8, 0)
        step = int.from_bytes(raw, "little") if raw else 0
        fs.pwrite(fd, (step + 1).to_bytes(8, "little"), 0)
        fs.close(fd)
        return step

    def peek(self, fs: FaaSFS, shard: int) -> int:
        p = f"{self.path}.{shard}"
        if not fs.exists(p):
            return 0
        fd = fs.open(p)
        raw = fs.pread(fd, 8, 0)
        fs.close(fd)
        return int.from_bytes(raw, "little") if raw else 0
