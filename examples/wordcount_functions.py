"""A multi-function serverless app on FaaSFS: word count, map/reduce style.

Run:  PYTHONPATH=src python examples/wordcount_functions.py

Three cloud functions share state purely through the filesystem — the
paper's programming model: "stateful server-based applications run with
little or no modification".

  ingest(doc, text)   writer  — store a document under /mnt/tsfs/wc/docs
  count_doc(doc)      writer  — tokenize one doc, merge counts into the
                                shared index (conflicts with concurrent
                                mergers -> transparent retry)
  top_words(n)        reader  — inferred read-only after its first run:
                                snapshot reads, no commit validation

Every invocation is one atomic transaction: a crash mid-`count_doc`
publishes nothing, a conflict restarts the function, and the final
`top_words` always sees a consistent index.
"""
import json
import re
import threading

from repro.core.backend import BackendService
from repro.core.client import LocalServer
from repro.core.posix import O_CREAT, O_RDWR, O_TRUNC
from repro.core.runtime import FunctionRuntime, InvocationStats
from repro.core.types import CachePolicy

DOCS = {
    "zen": "simple is better than complex complex is better than complicated",
    "posix": "everything is a file a file is a sequence of bytes",
    "faas": "a function is a transaction a transaction is a function",
    "cache": "warm containers keep the cache warm between function calls",
}


def main() -> None:
    backend = BackendService(block_size=4096, policy=CachePolicy.EAGER)
    # two warm "containers", each with its own cache-carrying runtime
    workers = [FunctionRuntime(LocalServer(backend)) for _ in range(2)]
    rt = workers[0]

    # ---- function 1: ingest raw documents -----------------------------
    @rt.function
    def ingest(fs, doc, text):
        fs.makedirs("/mnt/tsfs/wc/docs", exist_ok=True)
        fd = fs.open(f"/mnt/tsfs/wc/docs/{doc}", O_CREAT | O_TRUNC | O_RDWR)
        fs.write(fd, text.encode())
        fs.close(fd)

    for doc, text in DOCS.items():
        ingest(doc, text)
    print(f"ingested {len(DOCS)} docs ->", end=" ")

    @rt.function(read_only=True)
    def listing(fs):
        return fs.readdir("/mnt/tsfs/wc/docs")

    print(listing())

    # ---- function 2: count one doc, merge into the shared index -------
    def count_doc(fs, doc):
        fd = fs.open(f"/mnt/tsfs/wc/docs/{doc}")
        text = fs.pread(fd, fs.fstat(fd)["st_size"], 0).decode()
        counts = {}
        for w in re.findall(r"[a-z]+", text):
            counts[w] = counts.get(w, 0) + 1
        ifd = fs.open("/mnt/tsfs/wc/index.json", O_CREAT | O_RDWR)
        raw = fs.pread(ifd, fs.fstat(ifd)["st_size"], 0)
        index = json.loads(raw) if raw else {}
        for w, n in counts.items():
            index[w] = index.get(w, 0) + n
        data = json.dumps(index, sort_keys=True).encode()
        fs.ftruncate(ifd, 0)
        fs.pwrite(ifd, data, 0)
        fs.close(ifd)
        fs.close(fd)

    # all four docs counted CONCURRENTLY from two warm containers: the
    # read-modify-write of index.json conflicts; the runtime retries
    stats = [InvocationStats() for _ in DOCS]
    threads = [
        threading.Thread(
            target=workers[i % 2].invoke, args=(count_doc, doc),
            kwargs={"stats": stats[i]},
        )
        for i, doc in enumerate(DOCS)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    attempts = sum(s.attempts for s in stats)
    aborts = sum(s.aborts for s in stats)
    print(f"counted concurrently: {attempts} attempts, {aborts} conflicts "
          "retried transparently")

    # ---- function 3: read the index (inferred read-only) ---------------
    @rt.function
    def top_words(fs, n):
        fd = fs.open("/mnt/tsfs/wc/index.json")
        index = json.loads(fs.pread(fd, fs.fstat(fd)["st_size"], 0))
        return sorted(index.items(), key=lambda kv: (-kv[1], kv[0]))[:n]

    first = top_words(5)       # runs read-write, observes zero effects
    s = InvocationStats()
    second = top_words(5, stats=s)   # now on the inferred read-only fast path
    assert first == second
    print("top words:", ", ".join(f"{w}={n}" for w, n in second),
          f"(read_only inferred: {s.read_only})")

    # sanity: the index agrees with a direct recount
    expect = {}
    for text in DOCS.values():
        for w in re.findall(r"[a-z]+", text):
            expect[w] = expect.get(w, 0) + 1
    best = sorted(expect.items(), key=lambda kv: (-kv[1], kv[0]))[:5]
    assert second == best, (second, best)
    print("runtime stats:", rt.stats)


if __name__ == "__main__":
    main()
