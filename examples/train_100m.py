"""End-to-end driver: train a ~100M-param model with transactional state.

Every training step runs as a function-grained FaaSFS transaction (BEGIN ->
read params -> jit'd step -> COMMIT delta blocks), with atomic checkpoints
every ``--ckpt-every`` steps and crash-free restart: re-running this script
resumes from the last committed checkpoint.

Run:  PYTHONPATH=src python examples/train_100m.py --steps 300
      (use --d-model 128 --layers 4 for a quick CPU sanity pass)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.backend import BackendService
from repro.core.client import LocalServer
from repro.core.types import CachePolicy
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import model as M
from repro.models.runtime import CellPlan, make_train_step
from repro.optim import adamw
from repro.state.checkpoint import CheckpointManager
from repro.train.loop import TransactionalTrainer


def build_cfg(args) -> ModelConfig:
    return ModelConfig(
        name="train100m",
        family="dense",
        num_layers=args.layers,
        d_model=args.d_model,
        num_heads=args.d_model // 64,
        num_kv_heads=max(1, args.d_model // 256),
        head_dim=64,
        d_ff=args.d_model * 4,
        vocab_size=8192,
        tie_embeddings=True,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=768)   # ~100M params
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = build_cfg(args)
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.num_layers}L d{cfg.d_model})")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state0 = {"params": params, "opt": adamw.init_opt_state(params)}
    plan = CellPlan(cfg, ShapeCell("t", "train", args.seq, args.batch),
                    None, {}, M.NO_SHARDING, 0, 128)
    jit_step = jax.jit(make_train_step(
        plan, adamw.AdamWConfig(lr_peak=3e-4, warmup_steps=20, decay_steps=args.steps)
    ), donate_argnums=(0,))

    backend = BackendService(block_size=1 << 20, policy=CachePolicy.EAGER)
    local = LocalServer(backend)
    template = jax.tree.map(np.asarray, state0)

    def train_step(state, batch):
        jstate = jax.tree.map(jnp.asarray, state)
        jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
        new_state, metrics = jit_step(jstate, jbatch)
        return new_state, {k: float(v) for k, v in metrics.items()}

    trainer = TransactionalTrainer(local, train_step, template)
    cm = CheckpointManager(local, block_bytes=1 << 20)

    # resume if a checkpoint exists (crash/restart = just rerun the script)
    start = 0
    try:
        restored, start = cm.restore(template)
        trainer.init(restored)
        print(f"resumed from committed checkpoint @ step {start}")
    except FileNotFoundError:
        trainer.init(template)
        print("fresh start")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    t0 = time.time()
    for step in range(start, args.steps):
        res = trainer.step(synth_batch(dcfg, step))
        if step % 10 == 0:
            toks = args.batch * args.seq * (step + 1 - start)
            print(f"step {step:4d} loss={res.metrics['loss']:.4f} "
                  f"gnorm={res.metrics['grad_norm']:.2f} "
                  f"commit_bytes={res.bytes_written:,} "
                  f"tok/s={toks/ (time.time()-t0):,.0f}")
        if (step + 1) % args.ckpt_every == 0:
            info = cm.save(step + 1, trainer.read_state())
            print(f"  checkpoint @ {step+1}: {info.bytes_written:,} bytes "
                  f"({info.blocks_written} blocks, delta) in {info.wall_s:.2f}s")
    print(f"done: {trainer.stats.steps} steps, {trainer.stats.aborts} occ aborts, "
          f"{trainer.stats.commit_bytes/1e6:.1f}MB committed")


if __name__ == "__main__":
    main()
