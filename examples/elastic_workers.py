"""Elastic swarm training: workers join/leave mid-run, stragglers race.

Shows the FaaS fault-tolerance model applied to training:
  * workers are stateless functions — any of them can run any step,
  * membership changes are OCC commits on the topology file (no barriers);
    in-flight steps from the old generation abort + retry,
  * a duplicated ("backup") step commits exactly once — the loser aborts
    at validation,
  * a killed worker leaves NO partial state.

Run:  PYTHONPATH=src python examples/elastic_workers.py
"""
import threading
import time

import numpy as np

from repro.core.backend import BackendService
from repro.core.client import LocalServer
from repro.core.posix import FaaSFS
from repro.core.types import CachePolicy
from repro.serving.engine import SnapshotServer
from repro.train.elastic import ElasticCoordinator
from repro.train.loop import TransactionalTrainer


def template():
    return {"w": np.zeros((64, 64), np.float32), "count": np.int64(0)}


def make_step(coord: ElasticCoordinator):
    def train_step(state, batch):
        # a real step would shard work by the partition map; here we just
        # pull the state toward the batch
        g = state["w"] - batch
        return (
            {"w": state["w"] - 0.1 * g, "count": state["count"] + 1},
            {"loss": float(np.mean(g * g))},
        )

    return train_step


def main() -> None:
    backend = BackendService(block_size=65536, policy=CachePolicy.EAGER)
    coord = ElasticCoordinator(LocalServer(backend))
    coord.bootstrap(["w0"], {"w0": ["all"]})

    target = np.full((64, 64), 1.0, np.float32)
    stop = threading.Event()
    stats = {}

    def worker(name: str, delay: float = 0.0):
        time.sleep(delay)
        local = LocalServer(backend)
        if delay > 0:
            topo = ElasticCoordinator(local).join(name)
            print(f"[{name}] joined at generation {topo.generation}")
        tr = TransactionalTrainer(local, make_step(coord), template())
        while not stop.is_set():
            # each step reads the topology inside its txn: membership
            # changes invalidate in-flight steps (no barrier, no lease)
            res = tr.step(target)
        stats[name] = tr.stats
        print(f"[{name}] done: {tr.stats.steps} steps, {tr.stats.aborts} occ aborts")

    trainer0 = TransactionalTrainer(LocalServer(backend), make_step(coord), template())
    trainer0.init(template())

    threads = [
        threading.Thread(target=worker, args=("w0", 0.0)),
        threading.Thread(target=worker, args=("w1", 0.3)),   # elastic scale-up
        threading.Thread(target=worker, args=("w2", 0.6)),
    ]
    for t in threads:
        t.start()

    time.sleep(1.0)
    topo = ElasticCoordinator(LocalServer(backend)).leave("w2")  # scale-down
    print(f"[coord] w2 left; generation {topo.generation}, workers {topo.workers}")
    time.sleep(0.5)
    stop.set()
    for t in threads:
        t.join()

    final = trainer0.read_state()
    total_steps = int(np.asarray(final["count"]))
    committed = sum(s.steps for s in stats.values())
    print(f"\nfinal committed step count: {total_steps} "
          f"(== {committed} worker commits, exactly-once despite races)")
    assert total_steps == committed
    print("loss:", float(np.mean((final['w'] - target) ** 2)))


if __name__ == "__main__":
    main()
