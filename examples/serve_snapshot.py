"""Serve a model from pinned snapshots while training commits new versions.

Demonstrates the paper's multiversion snapshot reads as an ML-serving
feature: inference replicas serve a *consistent* parameter version with
zero coordination against the writer, then delta-refresh to newer commits.

Run:  PYTHONPATH=src python examples/serve_snapshot.py
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeCell, get_config, reduced_config
from repro.core.backend import BackendService
from repro.core.client import LocalServer
from repro.core.types import CachePolicy
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import model as M
from repro.models.runtime import CellPlan, make_train_step
from repro.optim import adamw
from repro.serving.engine import SnapshotServer
from repro.train.loop import TransactionalTrainer


def main() -> None:
    cfg = reduced_config(get_config("qwen2-1.5b"), num_layers=2, d_model=64,
                         d_ff=128, vocab_size=512)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state0 = jax.tree.map(np.asarray,
                          {"params": params, "opt": adamw.init_opt_state(params)})
    plan = CellPlan(cfg, ShapeCell("t", "train", 64, 4), None, {}, M.NO_SHARDING, 0, 32)
    jit_step = jax.jit(make_train_step(plan, adamw.AdamWConfig(lr_peak=1e-3)))

    backend = BackendService(block_size=1 << 18, policy=CachePolicy.EAGER)
    trainer = TransactionalTrainer(
        LocalServer(backend),
        lambda s, b: jit_step(jax.tree.map(jnp.asarray, s),
                              {k: jnp.asarray(v) for k, v in b.items()}),
        state0,
    )
    trainer.init(state0)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)

    # warm the jit caches so the background thread commits immediately
    jit_step(jax.tree.map(jnp.asarray, state0),
             {k: jnp.asarray(v) for k, v in synth_batch(dcfg, 0).items()})

    stop = threading.Event()

    def train_loop():
        step = 0
        while not stop.is_set():
            trainer.step(synth_batch(dcfg, step))
            step += 1

    t = threading.Thread(target=train_loop)
    t.start()

    # a serving replica pins snapshots and refreshes on its own schedule
    @jax.jit
    def greedy_decode(params, toks):
        logits, _ = M.prefill(cfg, params, toks, q_chunk=0)
        return jnp.argmax(logits, axis=-1)

    def decode_fn(state, toks):
        return np.asarray(greedy_decode(jax.tree.map(jnp.asarray, state["params"]),
                                        jnp.asarray(toks)))

    server = SnapshotServer(LocalServer(backend), decode_fn, state0)
    prompt = synth_batch(dcfg, 12345)["tokens"][:2, :16]
    decode_fn({"params": jax.tree.map(np.asarray, params)}, prompt)  # warm up
    try:
        for round_ in range(5):
            version = server.refresh()
            outs = [server.serve(prompt) for _ in range(3)]
            assert all(np.array_equal(outs[0], o) for o in outs), \
                "snapshot must be stable between refreshes"
            print(f"round {round_}: pinned version {version}, "
                  f"next tokens {outs[0].tolist()} "
                  f"(trainer committed {trainer.stats.steps} steps so far)")
            time.sleep(0.3)
    finally:
        stop.set()
        t.join()
    print(f"served {server.stats.requests} requests across "
          f"{server.stats.refreshes} snapshot versions while training ran")


if __name__ == "__main__":
    main()
