"""Quickstart: the FaaSFS core API in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.backend import BackendService
from repro.core.client import LocalServer
from repro.core.posix import FaaSFS, O_CREAT, O_RDWR
from repro.core.runtime import FunctionRuntime
from repro.core.tensorstate import TensorStore, unflatten_like
from repro.core.types import CachePolicy, Conflict


def run_function(local, fn, **kw):
    """Invoke ``fn`` as a cloud function (implicit BEGIN/COMMIT/retry).

    One-liner form of the function-first API; see docs/posix.md. For
    decorated functions use ``@runtime.function`` below."""
    return FunctionRuntime(local).invoke(fn, **kw)


def main() -> None:
    # ---- the backend service (paper: monolithic in-memory prototype) ----
    backend = BackendService(block_size=4096, policy=CachePolicy.EAGER)

    # ---- each worker gets a LocalServer (cache survives invocations);
    # readahead_blocks turns a sequential read's cache misses into ONE
    # batched fetch_blocks round trip that also warms the next blocks ----
    worker_a = LocalServer(backend, readahead_blocks=8)
    worker_b = LocalServer(backend, readahead_blocks=8)

    # ---- 1. a cloud function is an implicit transaction -----------------
    def write_config(fs: FaaSFS) -> None:
        fd = fs.open("/mnt/tsfs/app/config.json", O_CREAT)
        fs.write(fd, b'{"lr": 3e-4}')
        fs.close(fd)

    run_function(worker_a, write_config)
    print("1. committed config atomically at function return")

    # ---- 1b. the POSIX surface is errno-faithful: real directories,
    # access modes, vectored I/O, OSError subclasses with correct errno --
    def posix_surface(fs: FaaSFS) -> None:
        fs.makedirs("/mnt/tsfs/app/logs", exist_ok=True)
        assert fs.readdir("/mnt/tsfs/app") == ["config.json", "logs"]
        try:
            fs.rmdir("/mnt/tsfs/app")          # not empty
        except OSError as e:
            import errno as errno_mod
            assert e.errno == errno_mod.ENOTEMPTY
        fd = fs.open("/mnt/tsfs/app/logs/req", O_CREAT | O_RDWR)
        fs.pwritev(fd, [b"GET /", b" 200\n"], 0)   # one write, one iovec
        head, tail = fs.preadv(fd, [5, 5], 0)       # ONE fetch_blocks RPC
        assert head == b"GET /" and tail == b" 200\n"
        st = fs.stat("/mnt/tsfs/app/logs/req")      # full stat: size,
        assert st["st_size"] == 10                  # kind, mtime/ctime
        fs.close(fd)                                # (commit timestamps)

    run_function(worker_a, posix_surface)
    print("1b. errno-faithful VFS: real dirs, ENOTEMPTY, vectored I/O")

    # ---- 2. POSIX semantics: rename is atomic, reads are consistent -----
    def rotate(fs: FaaSFS) -> None:
        fd = fs.open("/mnt/tsfs/app/config.v2", O_CREAT)
        fs.write(fd, b'{"lr": 1e-4}')
        fs.close(fd)
        fs.rename("/mnt/tsfs/app/config.v2", "/mnt/tsfs/app/config.json")

    run_function(worker_a, rotate)
    print("2. atomic rename flipped the config")

    # ---- 3. optimistic concurrency: conflicts abort and retry -----------
    def bump_counter(fs: FaaSFS) -> None:
        fd = fs.open("/mnt/tsfs/app/counter", O_CREAT)
        raw = fs.pread(fd, 8, 0)
        n = int.from_bytes(raw, "little") if raw else 0
        fs.pwrite(fd, (n + 1).to_bytes(8, "little"), 0)

    import threading

    rt_a, rt_b = FunctionRuntime(worker_a), FunctionRuntime(worker_b)
    bump_a, bump_b = rt_a.function(bump_counter), rt_b.function(bump_counter)
    threads = [
        threading.Thread(target=lambda f=f: [f() for _ in range(50)])
        for f in (bump_a, bump_b)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    def read_counter(fs: FaaSFS) -> None:
        fd = fs.open("/mnt/tsfs/app/counter")
        print("3. counter after 2x50 concurrent increments:",
              int.from_bytes(fs.pread(fd, 8, 0), "little"),
              f"(aborts retried transparently; backend aborts={backend.stats.aborts})")

    run_function(worker_a, read_counter, read_only=True)

    # ---- 4. tensors as files: block-granular delta commits ---------------
    params = {"layer0": {"w": np.random.randn(256, 256).astype(np.float32)}}

    def save_params(fs: FaaSFS) -> None:
        TensorStore(fs).save("model", params, block_bytes=65536)

    run_function(worker_a, save_params)

    params2 = {"layer0": {"w": params["layer0"]["w"].copy()}}
    params2["layer0"]["w"][:4] += 0.01  # touch a slab
    stats = {}

    def save_delta(fs: FaaSFS) -> None:
        from repro.core.tensorstate import flatten_with_names
        base = {n: a for n, a in flatten_with_names(params)}
        stats.update(TensorStore(fs).save("model", params2, baseline=base, block_bytes=65536))

    run_function(worker_a, save_delta)
    print(f"4. delta commit shipped {stats['bytes_written']:,} of "
          f"{stats['bytes_total']:,} bytes ({stats['blocks_written']} dirty blocks)")

    # ---- 5. snapshot reads: consistent state while writers commit --------
    txn = worker_b.begin(read_only=True)
    fs = FaaSFS(txn)
    pinned = TensorStore(fs).load("model")["layer0/w"]
    run_function(worker_a, save_params)  # concurrent new version
    pinned_again = TensorStore(fs).load("model")["layer0/w"]
    assert np.array_equal(pinned, pinned_again)
    txn.commit()
    print("5. snapshot reader saw a consistent version despite concurrent commits")

    # ---- 6. batch-first API: plural ops and futures ----------------------
    # Every backend (in-process, sharded, networked) implements ONE batch
    # surface; scalar calls are shims. A batch is one logical round trip.
    txn = worker_a.begin(read_only=True)
    fid = txn.lookup("/mnt/tsfs/app/config.json")
    keys = [(fid, 0)]
    versions_and_blocks = backend.fetch_blocks(keys)       # one round trip
    futs = [backend.submit("fetch_block", k) for k in keys]  # pipelined form
    assert [f.result() for f in futs] == versions_and_blocks
    txn.abort()
    print(f"6. fetched {len(keys)} block(s) in one batched call; "
          "futures resolve out of band on networked transports")

    # ---- 7. the real thing: a networked server, pipelined client, and a
    # clean SIGTERM teardown (drains in-flight requests, flushes the WAL —
    # no torn log tail for the next start to truncate) ---------------------
    import os
    import signal
    import subprocess
    import sys
    import tempfile

    from repro.core.remote import RemoteBackend

    with tempfile.TemporaryDirectory() as td:
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.core.server",
             "--wal", os.path.join(td, "faasfs.wal")],
            stdout=subprocess.PIPE, text=True, env=env,
        )
        port = int(proc.stdout.readline().split()[1])
        rb = RemoteBackend("127.0.0.1", port)
        remote_worker = LocalServer(rb, readahead_blocks=8)

        def remote_write(fs: FaaSFS) -> None:
            fd = fs.open("/mnt/tsfs/remote/hello", O_CREAT)
            fs.write(fd, b"over the wire, durably")

        run_function(remote_worker, remote_write)
        rb.close()
        proc.send_signal(signal.SIGTERM)
        proc.wait(timeout=15)
        tail = proc.stdout.read().strip()
        print(f"7. remote commit fsync'd; server exited {proc.returncode} "
              f"({tail})")


if __name__ == "__main__":
    main()
